//! The in-memory datastore backing the orchestrator.
//!
//! Two backends mirror the paper's observation (§3.1) that swapping Redis
//! for its multithreaded fork KeyDB "provided significantly more
//! performance":
//!
//! * [`ShardedStore`] — N independently locked shards (KeyDB analogue):
//!   concurrent clients hitting different keys proceed in parallel.
//! * a 1-shard store — every operation serializes on one lock, the
//!   single-threaded-Redis analogue.
//!
//! Blocking reads come in two shapes, both condvar-backed (no
//! spin-polling): single-key ([`ShardedStore::wait_for`] /
//! [`ShardedStore::wait_take`], the SmartRedis `poll_tensor` analogue)
//! and multi-key ([`ShardedStore::wait_any`] /
//! [`ShardedStore::wait_any_take`]), the arrival-order subscription the
//! event-driven rollout collector consumes env states through.
//!
//! # Multi-key wakeup protocol ([`WakeMode`])
//!
//! The default, [`WakeMode::PerKey`], registers each subscriber on every
//! key it waits for, inside that key's shard: `put` wakes **only** the
//! waiters registered on the written key and hands each one the hit
//! index for its own key set, so a put on an unsubscribed key costs one
//! registry probe and a pool of hundreds of subscribers never rescans on
//! unrelated traffic.  Race guarantees:
//!
//! * **No lost wakeup.**  Registration and `put` both run under the
//!   key's shard lock: a subscriber either observes the value during its
//!   registration scan, or leaves a registration behind that any later
//!   `put` must see and wake.
//! * **Exactly-once takes.**  A `wait_any_take` hit removes the value
//!   under the shard lock; a racing taker that was woken for the same
//!   key finds it gone and goes back to waiting (each stored value is
//!   delivered to at most one consumer, and — absent `delete`/`clear` —
//!   to exactly one).
//! * **`clear` / `delete` races.**  Removing a key does not disturb
//!   registrations; a waiter whose key was cleared simply keeps waiting
//!   until the key is written again or its timeout elapses.  (`clear`
//!   also wakes single-key waiters so they re-check, preserving the PR-2
//!   behaviour.)
//! * **Spurious wakeups are benign.**  The registry is keyed by the
//!   key's FNV-1a hash (no per-registration string allocation); a
//!   colliding hash — or a hit consumed by a racing taker — wakes a
//!   subscriber which re-checks its key and re-parks.
//!
//! [`WakeMode::SeqLock`] retains the PR-2 store-level sequence lock
//! (every put bumps one counter and wakes every subscriber, which then
//! rescans its whole key set) as the measurable baseline: `bench_db`'s
//! subscriber-scaling series puts the two head to head, and
//! `hpc.db_seqlock_wake = true` selects it for a full training run.
//!
//! # Persistent subscriptions ([`Subscription`])
//!
//! `wait_any` is stateless — every call registers its whole key set and
//! deregisters it on return.  Consumers whose key set evolves
//! incrementally (the rollout collector retires one key and adds one or
//! two per event) instead hold a [`Subscription`]: registrations stay
//! live across waits under caller-chosen tags, [`Subscription::add`] /
//! [`Subscription::remove`] apply single-key deltas (one shard-locked
//! registry op each, counted in [`StoreStats::sub_ops`]), and
//! [`Subscription::wait_take`] consumes deliveries in arrival order.
//! The same no-lost-wakeup argument applies (registration and presence
//! check share the key's shard lock; already-present values are
//! self-delivered), and every delivery is re-checked against the store,
//! so racing takers, `delete`/`clear`, and tag retargeting degrade to
//! benign re-parks.  Subscriptions deliver under **both** wake modes:
//! `put` always services the per-key registry, which in seq-lock mode
//! only persistent handles populate.
//!
//! Keys can be interned ([`Key`]) to precompute the routing hash once;
//! [`crate::orchestrator::Protocol`] builds per-(env, step) handles so
//! the steady-state rollout loop does no string formatting or rehashing.
//!
//! `bench_db` regenerates the comparison (experiment A1 in DESIGN.md §6).

use super::value::Value;
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::hash::{BuildHasher, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(key: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a [`Hasher`] for the shard maps: protocol keys are short,
/// program-generated strings hashed on every map probe, and FNV beats the
/// default SipHash by a wide margin there (no DoS exposure — keys are
/// never attacker-controlled).
#[derive(Clone, Default)]
pub struct FnvBuildHasher;

impl BuildHasher for FnvBuildHasher {
    type Hasher = FnvHasher;
    fn build_hasher(&self) -> FnvHasher {
        FnvHasher(FNV_OFFSET)
    }
}

/// Streaming FNV-1a state (see [`FnvBuildHasher`]).
pub struct FnvHasher(u64);

impl Hasher for FnvHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }
}

/// An interned store key: the shared name plus its precomputed FNV-1a
/// hash, so a hot loop routes to a shard and probes the waiter registry
/// without rehashing, and `put` inserts the map key as a refcount bump
/// instead of allocating a fresh string per message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Key {
    name: Arc<str>,
    hash: u64,
}

impl Key {
    /// Intern a key name (hashes and allocates once).
    pub fn new(name: impl Into<String>) -> Key {
        let name: Arc<str> = Arc::from(name.into());
        let hash = fnv1a(&name);
        Key { name, hash }
    }

    /// The key name.
    pub fn name(&self) -> &str {
        &self.name
    }
}

impl std::fmt::Display for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

/// Anything usable as a store key: a plain string (hash computed, and
/// the stored map key allocated, per call) or an interned [`Key`] handle
/// (hash precomputed, map key shared by refcount).
pub trait KeyLike {
    /// The key name.
    fn name(&self) -> &str;
    /// FNV-1a hash of the name (shard routing + waiter registry).
    fn hash64(&self) -> u64;
    /// The name as a shared string for storage in the map — a refcount
    /// bump for interned keys, an allocation for plain strings.
    fn shared_name(&self) -> Arc<str>;
}

impl KeyLike for str {
    fn name(&self) -> &str {
        self
    }
    fn hash64(&self) -> u64 {
        fnv1a(self)
    }
    fn shared_name(&self) -> Arc<str> {
        Arc::from(self)
    }
}

impl KeyLike for String {
    fn name(&self) -> &str {
        self
    }
    fn hash64(&self) -> u64 {
        fnv1a(self)
    }
    fn shared_name(&self) -> Arc<str> {
        Arc::from(self.as_str())
    }
}

impl KeyLike for Key {
    fn name(&self) -> &str {
        &self.name
    }
    fn hash64(&self) -> u64 {
        self.hash
    }
    fn shared_name(&self) -> Arc<str> {
        self.name.clone()
    }
}

/// How `put`/`clear` wake multi-key subscribers (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WakeMode {
    /// Per-key waiter registration: a put wakes only that key's waiters
    /// and hands over the hit index.  O(1) per put; the default.
    #[default]
    PerKey,
    /// PR-2 store-level sequence lock: every put wakes every subscriber,
    /// each of which rescans its whole key set.  Retained as the bench
    /// baseline (`hpc.db_seqlock_wake`).
    SeqLock,
}

/// Operation counters (throughput metrics for the §Perf pass).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub hits: AtomicU64,
    pub poll_misses: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Multi-key waiter slots this store constructed; threads cache and
    /// recycle slots locally (and immediate hits need none), so this
    /// saturates at roughly one per subscribing thread.
    pub waiters_created: AtomicU64,
    /// Waiter-registry mutations (key add/remove) performed by persistent
    /// [`Subscription`] handles.  The O(E)-per-wave acceptance counter:
    /// a steady-state collection wave over `E` envs must advance this by
    /// O(E), where the per-event subscription rebuild it replaced cost
    /// O(E) registry ops per *event* (O(E²) per wave).
    pub sub_ops: AtomicU64,
    /// Data-plane request frames decoded by the exchange server against
    /// this store.  Control-plane traffic (`__relexi:ctl:*` keys —
    /// heartbeats, hello/begin/stop) and connection management
    /// (Bye/ShmOpen/Clear) are exempt, so this is the PR-9 acceptance
    /// counter: a batched rollout wave over `W` worker blocks and `T`
    /// steps must advance it by O(W·T), where the per-key wire pattern
    /// costs O(E·T).  Stays 0 in inproc/threads mode (no frames exist).
    pub frames: AtomicU64,
    /// Keys moved through batched multi-key ops (`put_many` /
    /// `take_many` / `wait_take_many`), on any backend.  0 means the
    /// per-key path served every op (the `batch_ops = off` A/B leg).
    pub batched_keys: AtomicU64,
}

/// Snapshot of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub hits: u64,
    pub poll_misses: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub waiters_created: u64,
    pub sub_ops: u64,
    pub frames: u64,
    pub batched_keys: u64,
}

/// A parked multi-key subscriber: `put` pushes the hit index into the
/// inbox (FIFO, so queued deliveries resolve in arrival order, matching
/// the `wait_any` contract) and signals the condvar.
#[derive(Default)]
struct Waiter {
    inbox: Mutex<VecDeque<usize>>,
    cv: Condvar,
}

/// One checkout of the waiter cache: the shared waiter slot plus the
/// deregistration list `(shard index, key hash)` of its live
/// registrations.  Leases are recycled so steady-state subscriptions
/// allocate nothing.
struct Lease {
    waiter: Arc<Waiter>,
    reg: Vec<(usize, u64)>,
}

/// Upper bound on cached leases per thread (a thread rarely nests
/// subscriptions, so 1 is typical; the bound only caps pathological
/// cases).
const LEASE_CACHE_CAP: usize = 8;

thread_local! {
    /// Recycled waiter slots.  Thread-local rather than store-level so
    /// checkout/checkin touch no shared lock at all — with hundreds of
    /// env workers each polling per RL step, a store-global lease mutex
    /// would reintroduce exactly the serialization point the per-key
    /// redesign removes.  A deregistered lease carries no store-specific
    /// state, so one cache serves every store on the thread.
    static LEASE_CACHE: RefCell<Vec<Lease>> = const { RefCell::new(Vec::new()) };
}

struct Shard {
    inner: Mutex<ShardInner>,
    /// Single-key waiters (`wait_for`/`wait_take`) park here.
    cv: Condvar,
}

/// Registrations on one key hash: `(waiter, index of the key in that
/// waiter's subscription slice)` — the index is what `put` hands over.
type KeyWaiters = Vec<(Arc<Waiter>, usize)>;

#[derive(Default)]
struct ShardInner {
    /// `Arc<str>` keys: a put with an interned [`Key`] stores the key as
    /// a refcount bump; lookups go through `Borrow<str>`.
    map: HashMap<Arc<str>, Value, FnvBuildHasher>,
    /// Per-key waiter registrations, keyed by the key's FNV hash rather
    /// than the string (no allocation per registration; a colliding hash
    /// only produces a benign spurious wakeup).  Deregistration leaves
    /// empty entries behind to avoid hot-path map churn; `clear` prunes
    /// them.
    waiters: HashMap<u64, KeyWaiters, FnvBuildHasher>,
}

/// Store-wide notifier for the [`WakeMode::SeqLock`] baseline: every
/// mutation that could satisfy a subscription bumps `seq` and wakes all
/// subscribers, which then re-scan their key sets.  The `waiters` count
/// keeps the common case (no subscriber) to one atomic load.
#[derive(Default)]
struct MultiWait {
    seq: Mutex<u64>,
    cv: Condvar,
    waiters: AtomicUsize,
}

impl MultiWait {
    fn bump(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut seq = self.seq.lock().unwrap();
        *seq = seq.wrapping_add(1);
        self.cv.notify_all();
    }
}

/// Check a waiter slot out of the thread-local cache (fresh slots are
/// counted per store; a steady-state thread reuses its slot forever).
fn checkout_lease(stats: &StoreStats) -> Lease {
    if let Some(lease) = LEASE_CACHE.with(|c| c.borrow_mut().pop()) {
        return lease;
    }
    stats.waiters_created.fetch_add(1, Ordering::Relaxed);
    Lease {
        waiter: Arc::new(Waiter::default()),
        reg: Vec::new(),
    }
}

/// Decrements the subscriber count on every exit path of the seq-lock
/// `wait_any` path.
struct SeqWaiterGuard<'a>(&'a AtomicUsize);

impl Drop for SeqWaiterGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Sharded in-memory key-value store.
pub struct ShardedStore {
    shards: Vec<Shard>,
    wake: WakeMode,
    multi: MultiWait,
    stats: StoreStats,
}

impl ShardedStore {
    /// Create a store with `shards` independent locks (1 = Redis-like)
    /// and the default per-key wakeup protocol.
    pub fn new(shards: usize) -> ShardedStore {
        ShardedStore::with_wake_mode(shards, WakeMode::PerKey)
    }

    /// Create a store with an explicit multi-key wakeup protocol
    /// ([`WakeMode::SeqLock`] retains the PR-2 baseline for benches).
    pub fn with_wake_mode(shards: usize, wake: WakeMode) -> ShardedStore {
        assert!(shards >= 1);
        ShardedStore {
            shards: (0..shards)
                .map(|_| Shard {
                    inner: Mutex::new(ShardInner::default()),
                    cv: Condvar::new(),
                })
                .collect(),
            wake,
            multi: MultiWait::default(),
            stats: StoreStats::default(),
        }
    }

    fn shard_index(&self, hash: u64) -> usize {
        // Route on the HIGH bits: the intra-shard map probes on the low
        // bits of the same FNV hash, so using the low bits here too would
        // leave every key in a shard sharing its probe-start bits
        // (clustered probe chains).  High and low halves of FNV-1a are
        // effectively independent.
        ((hash >> 32) as usize) % self.shards.len()
    }

    fn shard_at(&self, hash: u64) -> &Shard {
        &self.shards[self.shard_index(hash)]
    }

    /// Number of shards (1 = single-lock backend).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The configured multi-key wakeup protocol.
    pub fn wake_mode(&self) -> WakeMode {
        self.wake
    }

    fn count_hit(&self, v: &Value) {
        self.stats.hits.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_out
            .fetch_add(v.size_bytes() as u64, Ordering::Relaxed);
    }

    /// Store a value under a key (overwrites), waking pollers: single-key
    /// waiters on the shard, plus — per [`WakeMode`] — either exactly the
    /// waiters registered on this key (hit index handed over directly) or
    /// every subscriber via the sequence lock.
    pub fn put<K: KeyLike + ?Sized>(&self, key: &K, value: Value) {
        let _t = crate::util::telemetry::HistId::StorePut.timer();
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_in
            .fetch_add(value.size_bytes() as u64, Ordering::Relaxed);
        let h = key.hash64();
        let name = key.shared_name(); // outside the lock (may allocate for &str)
        let shard = self.shard_at(h);
        let mut inner = shard.inner.lock().unwrap();
        inner.map.insert(name, value);
        shard.cv.notify_all();
        // Per-key waiter delivery runs in BOTH wake modes: in seq-lock
        // mode `wait_any` never registers here, so the registry only
        // holds persistent [`Subscription`] handles — which must keep
        // working under the baseline protocol too.
        if let Some(ws) = inner.waiters.get(&h) {
            for (w, idx) in ws {
                w.inbox.lock().unwrap().push_back(*idx);
                w.cv.notify_one();
            }
        }
        if self.wake == WakeMode::SeqLock {
            drop(inner);
            self.multi.bump();
        }
    }

    /// Batched [`ShardedStore::put`]: hash every key outside any lock,
    /// sort by shard, and take each shard's lock exactly **once** for
    /// its whole group (vs once per key for a put loop).  Per-key
    /// semantics are identical — same waiter delivery, same single-key
    /// condvar wake, same seq-lock bump — so batched and per-key paths
    /// are observably equivalent except for lock traffic.
    pub fn put_many<K: KeyLike>(&self, items: Vec<(K, Value)>) {
        if items.is_empty() {
            return;
        }
        let _t = crate::util::telemetry::HistId::StorePutMany.timer();
        self.stats.puts.fetch_add(items.len() as u64, Ordering::Relaxed);
        self.stats
            .batched_keys
            .fetch_add(items.len() as u64, Ordering::Relaxed);
        let mut staged: Vec<(usize, u64, Arc<str>, Value)> = items
            .into_iter()
            .map(|(k, v)| {
                let h = k.hash64();
                (self.shard_index(h), h, k.shared_name(), v)
            })
            .collect();
        staged.sort_by_key(|e| e.0);
        let mut it = staged.into_iter().peekable();
        while let Some(si) = it.peek().map(|e| e.0) {
            let shard = &self.shards[si];
            let mut inner = shard.inner.lock().unwrap();
            while let Some((_, h, name, value)) = it.next_if(|e| e.0 == si) {
                self.stats
                    .bytes_in
                    .fetch_add(value.size_bytes() as u64, Ordering::Relaxed);
                inner.map.insert(name, value);
                if let Some(ws) = inner.waiters.get(&h) {
                    for (w, idx) in ws {
                        w.inbox.lock().unwrap().push_back(*idx);
                        w.cv.notify_one();
                    }
                }
            }
            shard.cv.notify_all();
        }
        if self.wake == WakeMode::SeqLock {
            self.multi.bump();
        }
    }

    /// Non-blocking batched take: atomically consume every present key
    /// of `keys` (one shard lock per group, like
    /// [`ShardedStore::put_many`]) and return `(index, value)` pairs in
    /// ascending index order.  Exactly-once holds per key: removal
    /// happens under the key's shard lock, so racing batched or
    /// single-key takers split the stream without loss or duplication.
    pub fn take_many<K: KeyLike + ?Sized>(&self, keys: &[&K]) -> Vec<(usize, Value)> {
        if keys.is_empty() {
            return Vec::new();
        }
        let _t = crate::util::telemetry::HistId::StoreTakeMany.timer();
        self.stats.gets.fetch_add(keys.len() as u64, Ordering::Relaxed);
        self.stats
            .batched_keys
            .fetch_add(keys.len() as u64, Ordering::Relaxed);
        let mut order: Vec<(usize, usize)> = keys
            .iter()
            .enumerate()
            .map(|(i, k)| (self.shard_index(k.hash64()), i))
            .collect();
        order.sort_unstable();
        let mut out = Vec::new();
        let mut p = 0;
        while p < order.len() {
            let si = order[p].0;
            let mut inner = self.shards[si].inner.lock().unwrap();
            while p < order.len() && order[p].0 == si {
                let i = order[p].1;
                if let Some(v) = inner.map.remove(keys[i].name()) {
                    self.count_hit(&v);
                    out.push((i, v));
                }
                p += 1;
            }
        }
        out.sort_unstable_by_key(|e| e.0);
        out
    }

    /// Blocking batched take: wait until **any** of `keys` is present,
    /// then atomically consume **all** present ones (the batched
    /// worker's one-wait-per-step primitive).  Returns an empty vec on
    /// timeout.  A waiter that is woken but finds its values stolen by
    /// a racing taker simply re-parks — only the grouped
    /// [`ShardedStore::take_many`] pass consumes, so exactly-once
    /// transfers from the store unchanged.
    pub fn take_many_wait<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
    ) -> Vec<(usize, Value)> {
        if keys.is_empty() {
            return Vec::new();
        }
        let deadline = Instant::now() + timeout;
        loop {
            let got = self.take_many(keys);
            if !got.is_empty() {
                return got;
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            // Park non-consumingly until any key is put; the registration
            // scan inside wait_any re-checks presence under each shard
            // lock, so a put landing between the take above and this
            // wait is observed, never lost.
            let _ = self.wait_any(keys, deadline - now);
        }
    }

    /// Fetch the value, if present.  Tensor/byte payloads are shared —
    /// the returned clone is a refcount bump, not a deep copy.
    pub fn get<K: KeyLike + ?Sized>(&self, key: &K) -> Option<Value> {
        let _t = crate::util::telemetry::HistId::StoreGet.timer();
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        let inner = self.shard_at(key.hash64()).inner.lock().unwrap();
        let v = inner.map.get(key.name()).cloned();
        if let Some(ref val) = v {
            self.count_hit(val);
        }
        v
    }

    /// Atomically fetch and remove (consume a message).
    pub fn take<K: KeyLike + ?Sized>(&self, key: &K) -> Option<Value> {
        let _t = crate::util::telemetry::HistId::StoreTake.timer();
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.shard_at(key.hash64()).inner.lock().unwrap();
        let v = inner.map.remove(key.name());
        if let Some(ref val) = v {
            self.count_hit(val);
        }
        v
    }

    /// Does the key exist?
    pub fn exists<K: KeyLike + ?Sized>(&self, key: &K) -> bool {
        self.shard_at(key.hash64())
            .inner
            .lock()
            .unwrap()
            .map
            .contains_key(key.name())
    }

    /// Remove a key; true if it existed.  Registered waiters are left
    /// untouched: they keep waiting for the next put or their timeout.
    pub fn delete<K: KeyLike + ?Sized>(&self, key: &K) -> bool {
        self.shard_at(key.hash64())
            .inner
            .lock()
            .unwrap()
            .map
            .remove(key.name())
            .is_some()
    }

    /// Remove everything (between training iterations).  Single-key
    /// waiters are woken so they re-check and, finding their keys gone,
    /// go back to waiting until their timeout.  Per-key registrations
    /// survive (a cleared key simply never delivers); registry entries
    /// whose waiters have all deregistered are pruned here, off the hot
    /// path.
    pub fn clear(&self) {
        for s in &self.shards {
            let mut inner = s.inner.lock().unwrap();
            inner.map.clear();
            inner.waiters.retain(|_, ws| !ws.is_empty());
            s.cv.notify_all();
        }
        if self.wake == WakeMode::SeqLock {
            self.multi.bump();
        }
    }

    /// Total number of stored keys.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.inner.lock().unwrap().map.len())
            .sum()
    }

    /// True if no keys are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking poll: wait until `key` appears (condvar-backed, the
    /// SmartRedis `poll_tensor` analogue) or `timeout` elapses.
    pub fn wait_for<K: KeyLike + ?Sized>(&self, key: &K, timeout: Duration) -> Option<Value> {
        self.wait_single(key, timeout, false)
    }

    /// Blocking poll-and-take: wait until `key` appears, then consume it.
    pub fn wait_take<K: KeyLike + ?Sized>(&self, key: &K, timeout: Duration) -> Option<Value> {
        self.wait_single(key, timeout, true)
    }

    fn wait_single<K: KeyLike + ?Sized>(
        &self,
        key: &K,
        timeout: Duration,
        take: bool,
    ) -> Option<Value> {
        let deadline = Instant::now() + timeout;
        let shard = self.shard_at(key.hash64());
        let mut inner = shard.inner.lock().unwrap();
        loop {
            let hit = if take {
                inner.map.remove(key.name())
            } else {
                inner.map.get(key.name()).cloned()
            };
            if let Some(v) = hit {
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.count_hit(&v);
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let (g, res) = shard.cv.wait_timeout(inner, deadline - now).unwrap();
            inner = g;
            if res.timed_out() && !inner.map.contains_key(key.name()) {
                return None;
            }
        }
    }

    /// Blocking multi-key subscription: wait until **any** of `keys`
    /// appears and return `(index, value)` for the first one found.
    /// Keys already present when the call starts are found in argument
    /// order (earlier keys win ties); afterwards whichever key's put
    /// arrives first wins.  Returns `None` on timeout.
    ///
    /// This is the arrival-order primitive behind the event-driven rollout
    /// collector: instead of blocking on one env's state while others sit
    /// ready (the per-key `poll` pattern whose synchronization overhead
    /// paper §6.2 measures), the trainer subscribes to every outstanding
    /// key at once and is woken by whichever env finishes first.
    /// Condvar-backed — no spin-polling; see the module docs for the
    /// wakeup protocol and its race guarantees.
    pub fn wait_any<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
    ) -> Option<(usize, Value)> {
        self.wait_any_impl(keys, timeout, false)
    }

    /// Like [`ShardedStore::wait_any`], but atomically consumes the value
    /// it returns (at most one key is removed per call; concurrent takers
    /// split a stream of puts without loss or duplication).
    pub fn wait_any_take<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
    ) -> Option<(usize, Value)> {
        self.wait_any_impl(keys, timeout, true)
    }

    fn wait_any_impl<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
        take: bool,
    ) -> Option<(usize, Value)> {
        if keys.is_empty() {
            return None;
        }
        let deadline = Instant::now() + timeout;
        match self.wake {
            WakeMode::PerKey => self.wait_any_perkey(keys, deadline, take),
            WakeMode::SeqLock => self.wait_any_seqlock(keys, deadline, take),
        }
    }

    /// Per-key path: register on every key (or return an existing value
    /// straight from the registration scan), then park on the waiter's
    /// own condvar until a put hands over a hit index.
    fn wait_any_perkey<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        deadline: Instant,
        take: bool,
    ) -> Option<(usize, Value)> {
        // Fast path: an already-present key (the collector's common case
        // when events are queued up) returns without touching the lease
        // cache or the registries at all.  Purely opportunistic — the
        // registration scan below re-checks presence authoritatively.
        for (i, key) in keys.iter().enumerate() {
            let mut inner = self.shard_at(key.hash64()).inner.lock().unwrap();
            let hit = if take {
                inner.map.remove(key.name())
            } else {
                inner.map.get(key.name()).cloned()
            };
            if let Some(v) = hit {
                drop(inner);
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.count_hit(&v);
                return Some((i, v));
            }
        }

        let mut lease = checkout_lease(&self.stats);
        // Registration scan: under each key's shard lock, either observe
        // the value now or leave a registration that any later put must
        // see (the no-lost-wakeup invariant).
        for (i, key) in keys.iter().enumerate() {
            let h = key.hash64();
            let si = self.shard_index(h);
            let mut inner = self.shards[si].inner.lock().unwrap();
            let hit = if take {
                inner.map.remove(key.name())
            } else {
                inner.map.get(key.name()).cloned()
            };
            if let Some(v) = hit {
                drop(inner);
                self.finish_lease(lease);
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.count_hit(&v);
                return Some((i, v));
            }
            inner
                .waiters
                .entry(h)
                .or_default()
                .push((lease.waiter.clone(), i));
            drop(inner);
            lease.reg.push((si, h));
        }

        loop {
            // Park until a put delivers a hit index or the deadline hits.
            let delivered = {
                let mut inbox = lease.waiter.inbox.lock().unwrap();
                loop {
                    if let Some(i) = inbox.pop_front() {
                        break Some(i);
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break None;
                    }
                    self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
                    let (g, _res) = lease.waiter.cv.wait_timeout(inbox, deadline - now).unwrap();
                    inbox = g;
                }
            };
            let Some(i) = delivered else {
                self.finish_lease(lease);
                return None;
            };
            if i >= keys.len() {
                continue; // defensive: stale index can't match this key set
            }
            // Re-check the delivered key: a racing taker, delete or clear
            // may have consumed it, in which case we simply re-park (the
            // registrations are still live).
            let hit = {
                let mut inner = self.shard_at(keys[i].hash64()).inner.lock().unwrap();
                if take {
                    inner.map.remove(keys[i].name())
                } else {
                    inner.map.get(keys[i].name()).cloned()
                }
            };
            if let Some(v) = hit {
                self.finish_lease(lease);
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.count_hit(&v);
                return Some((i, v));
            }
        }
    }

    /// Seq-lock baseline (PR-2 semantics, kept for `bench_db`'s
    /// head-to-head): park on the store-level sequence lock; every put
    /// anywhere triggers a full rescan of the key set.
    fn wait_any_seqlock<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        deadline: Instant,
        take: bool,
    ) -> Option<(usize, Value)> {
        // Register before the first scan: a put that misses the waiter
        // count must have completed its insert already, so the scan below
        // observes the key; a put that sees the count bumps `seq`.
        self.multi.waiters.fetch_add(1, Ordering::SeqCst);
        let _guard = SeqWaiterGuard(&self.multi.waiters);
        loop {
            // Snapshot the sequence BEFORE scanning: a put landing during
            // the scan advances it and turns the wait below into a rescan.
            let seq0 = *self.multi.seq.lock().unwrap();
            for (i, key) in keys.iter().enumerate() {
                let hit = if take { self.take(*key) } else { self.get(*key) };
                if let Some(v) = hit {
                    return Some((i, v));
                }
            }
            // Re-check the deadline after every scan: sustained puts on
            // unrelated keys keep advancing `seq`, and without this the
            // rescan loop would never consult the timeout.
            if Instant::now() >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let mut seq = self.multi.seq.lock().unwrap();
            while *seq == seq0 {
                let now = Instant::now();
                if now >= deadline {
                    return None;
                }
                let (s, res) = self.multi.cv.wait_timeout(seq, deadline - now).unwrap();
                seq = s;
                if res.timed_out() && *seq == seq0 {
                    return None;
                }
            }
        }
    }

    /// Deregister every live registration of the lease, wipe deliveries
    /// that raced the deregistration, and return the slot to the
    /// thread-local cache.  After the shard-locked removals no put can
    /// deliver to this waiter again, so the cached slot is inert.
    fn finish_lease(&self, mut lease: Lease) {
        for (si, h) in lease.reg.drain(..) {
            let mut inner = self.shards[si].inner.lock().unwrap();
            if let Some(ws) = inner.waiters.get_mut(&h) {
                ws.retain(|(w, _)| !Arc::ptr_eq(w, &lease.waiter));
            }
        }
        lease.waiter.inbox.lock().unwrap().clear();
        LEASE_CACHE.with(|c| {
            let mut cache = c.borrow_mut();
            if cache.len() < LEASE_CACHE_CAP {
                cache.push(lease);
            }
        });
    }

    /// Snapshot the op counters.
    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            puts: self.stats.puts.load(Ordering::Relaxed),
            gets: self.stats.gets.load(Ordering::Relaxed),
            hits: self.stats.hits.load(Ordering::Relaxed),
            poll_misses: self.stats.poll_misses.load(Ordering::Relaxed),
            bytes_in: self.stats.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.stats.bytes_out.load(Ordering::Relaxed),
            waiters_created: self.stats.waiters_created.load(Ordering::Relaxed),
            sub_ops: self.stats.sub_ops.load(Ordering::Relaxed),
            frames: self.stats.frames.load(Ordering::Relaxed),
            batched_keys: self.stats.batched_keys.load(Ordering::Relaxed),
        }
    }

    /// Count one data-plane request frame (called by the exchange
    /// server per decoded request; see [`StoreStats::frames`] for the
    /// control-plane exemptions the caller applies).
    pub(crate) fn note_frame(&self) {
        self.stats.frames.fetch_add(1, Ordering::Relaxed);
    }
}

/// A persistent, incrementally-updated multi-key subscription.
///
/// [`ShardedStore::wait_any`] is stateless: every call registers the
/// whole key set and deregisters it on return — O(set) shard-lock ops
/// per call.  For the rollout collector, whose key set changes by one or
/// two keys per event, that rebuild made a collection wave over `E` envs
/// cost O(E²) registry ops.  A `Subscription` keeps its registrations
/// **live across waits** under caller-chosen integer tags:
///
/// * [`Subscription::add`] registers one key under a tag (1 registry
///   op).  If the value is already present, the tag is self-delivered —
///   the same no-lost-wakeup guarantee as `wait_any`'s registration
///   scan, since the presence check and the registration happen under
///   the key's shard lock.
/// * [`Subscription::remove`] drops one tag's registration (1 op).
///   Queued deliveries for the tag become stale and are skipped (a
///   delivery is only honored against the tag's *current* key).
/// * [`Subscription::wait_take`] blocks until any registered key is
///   delivered, consumes the value, and returns `(tag, value)`.
///   Re-adding a tag (or a racing taker) is safe: every delivery is
///   re-checked against the store before it is returned.
///
/// Dropping the subscription deregisters everything.  Registry
/// mutations are counted in [`StoreStats::sub_ops`], which is what the
/// O(E)-per-wave collector test asserts on.
///
/// Unlike `wait_any`, the registration (not argument order) defines the
/// delivery priority: values present at `add` time and later puts are
/// delivered in arrival order through one FIFO inbox.
pub struct Subscription {
    store: Arc<ShardedStore>,
    waiter: Arc<Waiter>,
    /// `slots[tag]`: the tag's live registration (shard index, key hash,
    /// key name), or `None`.
    slots: Vec<Option<(usize, u64, Arc<str>)>>,
}

impl Subscription {
    /// Create a persistent subscription on `store`: register interest
    /// once, incrementally add and remove keys between waits, and
    /// receive per-key deliveries without ever rebuilding the key set.
    pub fn new(store: Arc<ShardedStore>) -> Subscription {
        Subscription {
            store,
            waiter: Arc::new(Waiter::default()),
            slots: Vec::new(),
        }
    }

    /// Register `key` under `tag` (replacing the tag's previous key, if
    /// any).  One registry op — plus a self-delivery if the value is
    /// already present, so a later [`Subscription::wait_take`] cannot
    /// miss it.
    pub fn add<K: KeyLike + ?Sized>(&mut self, tag: usize, key: &K) {
        self.remove(tag);
        if self.slots.len() <= tag {
            self.slots.resize_with(tag + 1, || None);
        }
        let h = key.hash64();
        let name = key.shared_name();
        let si = self.store.shard_index(h);
        let present = {
            let mut inner = self.store.shards[si].inner.lock().unwrap();
            inner
                .waiters
                .entry(h)
                .or_default()
                .push((self.waiter.clone(), tag));
            inner.map.contains_key(&*name)
        };
        self.store.stats.sub_ops.fetch_add(1, Ordering::Relaxed);
        if present {
            // The value predates the registration, so no put will
            // announce it: deliver the tag ourselves.
            self.waiter.inbox.lock().unwrap().push_back(tag);
        }
        self.slots[tag] = Some((si, h, name));
    }

    /// Deregister whatever key `tag` is registered for (no-op for an
    /// unregistered tag).  One registry op.
    pub fn remove(&mut self, tag: usize) {
        let Some(reg) = self.slots.get_mut(tag).and_then(Option::take) else {
            return;
        };
        let (si, h, _name) = reg;
        let mut inner = self.store.shards[si].inner.lock().unwrap();
        if let Some(ws) = inner.waiters.get_mut(&h) {
            ws.retain(|(w, t)| !(Arc::ptr_eq(w, &self.waiter) && *t == tag));
        }
        drop(inner);
        self.store.stats.sub_ops.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of live registrations.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True if no key is registered.
    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Block until any registered key holds a value, consume it, and
    /// return `(tag, value)`; `None` on timeout.  Stale deliveries
    /// (removed tags, values consumed by racing takers, cleared keys)
    /// are skipped and the wait continues.
    pub fn wait_take(&mut self, timeout: Duration) -> Option<(usize, Value)> {
        let deadline = Instant::now() + timeout;
        loop {
            let delivered = {
                let mut inbox = self.waiter.inbox.lock().unwrap();
                loop {
                    if let Some(t) = inbox.pop_front() {
                        break Some(t);
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break None;
                    }
                    self.store.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
                    let (g, _res) = self.waiter.cv.wait_timeout(inbox, deadline - now).unwrap();
                    inbox = g;
                }
            };
            let tag = delivered?;
            // Honor the delivery only against the tag's CURRENT key, and
            // re-check the store authoritatively: a racing taker, delete
            // or clear may have consumed the value (re-park), and a
            // remove+add may have retargeted the tag since the put.
            let Some(Some((si, _h, name))) = self.slots.get(tag) else {
                continue;
            };
            let hit = {
                let mut inner = self.store.shards[*si].inner.lock().unwrap();
                inner.map.remove(&**name)
            };
            if let Some(v) = hit {
                self.store.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.store.count_hit(&v);
                return Some((tag, v));
            }
        }
    }

    /// Batched [`Subscription::wait_take`]: block until the first
    /// delivery, then drain up to `max - 1` further queued deliveries
    /// without blocking again.  Every returned `(tag, value)` passes
    /// the same current-key honor + authoritative store re-check as
    /// `wait_take`, so exactly-once consumption holds under racing
    /// takers, retargeted tags and `delete`/`clear`; stale deliveries
    /// are skipped, never returned.  Empty vec = timeout.
    pub fn wait_take_many(&mut self, timeout: Duration, max: usize) -> Vec<(usize, Value)> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        let Some(first) = self.wait_take(timeout) else {
            return out;
        };
        out.push(first);
        while out.len() < max {
            let Some(tag) = self.waiter.inbox.lock().unwrap().pop_front() else {
                break;
            };
            let Some(Some((si, _h, name))) = self.slots.get(tag) else {
                continue;
            };
            let hit = {
                let mut inner = self.store.shards[*si].inner.lock().unwrap();
                inner.map.remove(&**name)
            };
            if let Some(v) = hit {
                self.store.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.store.count_hit(&v);
                out.push((tag, v));
            }
        }
        self.store
            .stats
            .batched_keys
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        out
    }
}

impl Drop for Subscription {
    fn drop(&mut self) {
        for tag in 0..self.slots.len() {
            self.remove(tag);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const MODES: [WakeMode; 2] = [WakeMode::PerKey, WakeMode::SeqLock];

    #[test]
    fn put_get_take() {
        let s = ShardedStore::new(4);
        s.put("a", Value::Scalar(1.5));
        assert_eq!(s.get("a"), Some(Value::Scalar(1.5)));
        assert_eq!(s.take("a"), Some(Value::Scalar(1.5)));
        assert_eq!(s.get("a"), None);
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_and_delete() {
        let s = ShardedStore::new(2);
        s.put("k", Value::Flag(false));
        s.put("k", Value::Flag(true));
        assert_eq!(s.get("k").unwrap().as_flag(), Some(true));
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
    }

    #[test]
    fn interned_keys_interoperate_with_strings() {
        let s = ShardedStore::new(8);
        let k = Key::new("e0:s0:state");
        assert_eq!(k.hash64(), "e0:s0:state".hash64());
        s.put(&k, Value::Scalar(4.0));
        assert_eq!(s.get("e0:s0:state"), Some(Value::Scalar(4.0)));
        s.put("e0:s0:state", Value::Scalar(5.0));
        assert_eq!(s.take(&k), Some(Value::Scalar(5.0)));
        assert!(!s.exists(&k));
        assert_eq!(k.name(), "e0:s0:state");
        assert_eq!(k.to_string(), "e0:s0:state");
    }

    #[test]
    fn get_is_zero_copy_of_the_put_tensor() {
        // Acceptance gate: a 48³-scale state tensor round-trips through
        // put/get/wait_any as a refcount bump on the producer's buffer.
        let s = ShardedStore::new(4);
        let data: Arc<[f32]> = Arc::from(vec![0.5f32; 48 * 48 * 48 * 3]);
        let shape: Arc<[usize]> = Arc::from(vec![data.len()]);
        s.put("state", Value::tensor_shared(shape, data.clone()));
        let g1 = s.get("state").unwrap().tensor_data().unwrap();
        let g2 = s.get("state").unwrap().tensor_data().unwrap();
        assert!(Arc::ptr_eq(&g1, &data), "get must not deep-copy");
        assert!(Arc::ptr_eq(&g2, &data));
        let (_, v) = s.wait_any(&["state"], Duration::from_secs(1)).unwrap();
        assert!(
            Arc::ptr_eq(&v.tensor_data().unwrap(), &data),
            "wait_any must not deep-copy"
        );
    }

    #[test]
    fn wait_for_times_out() {
        let s = ShardedStore::new(1);
        let t0 = Instant::now();
        assert!(s.wait_for("nope", Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn wait_for_sees_concurrent_put() {
        let s = Arc::new(ShardedStore::new(4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.put("late", Value::Scalar(7.0));
        });
        let v = s.wait_for("late", Duration::from_secs(2));
        h.join().unwrap();
        assert_eq!(v, Some(Value::Scalar(7.0)));
    }

    #[test]
    fn wait_take_consumes() {
        let s = Arc::new(ShardedStore::new(4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.put("x", Value::Scalar(1.0));
        });
        assert!(s.wait_take("x", Duration::from_secs(2)).is_some());
        h.join().unwrap();
        assert!(!s.exists("x"));
    }

    #[test]
    fn concurrent_clients_consistent() {
        let s = Arc::new(ShardedStore::new(8));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s.put(&format!("t{t}:k{i}"), Value::Scalar(i as f64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 800);
        let st = s.stats();
        assert_eq!(st.puts, 800);
        for t in 0..8 {
            for i in (0..100).step_by(17) {
                assert_eq!(
                    s.get(&format!("t{t}:k{i}")).unwrap().as_scalar(),
                    Some(i as f64)
                );
            }
        }
    }

    #[test]
    fn wait_any_returns_existing_key_with_priority() {
        for mode in MODES {
            let s = ShardedStore::with_wake_mode(4, mode);
            s.put("b", Value::Scalar(2.0));
            s.put("a", Value::Scalar(1.0));
            // Argument order, not insertion order, breaks the tie.
            let (i, v) = s
                .wait_any(&["a", "b"], Duration::from_secs(1))
                .expect("both present");
            assert_eq!((i, v), (0, Value::Scalar(1.0)), "{mode:?}");
            // Non-consuming: both keys still there.
            assert!(s.exists("a") && s.exists("b"));
        }
    }

    #[test]
    fn wait_any_times_out_empty_and_missing() {
        for mode in MODES {
            let s = ShardedStore::with_wake_mode(2, mode);
            assert!(s.wait_any::<str>(&[], Duration::from_secs(5)).is_none());
            let t0 = Instant::now();
            assert!(s
                .wait_any(&["x", "y"], Duration::from_millis(30))
                .is_none());
            assert!(t0.elapsed() >= Duration::from_millis(25), "{mode:?}");
            assert!(t0.elapsed() < Duration::from_secs(4), "{mode:?}");
        }
    }

    #[test]
    fn wait_any_sees_concurrent_put_on_any_key() {
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(8, mode));
            let s2 = s.clone();
            let h = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                s2.put("k7", Value::Scalar(7.0));
            });
            let (i, v) = s
                .wait_any(&["k3", "k5", "k7"], Duration::from_secs(5))
                .expect("concurrent put must wake the waiter");
            h.join().unwrap();
            assert_eq!((i, v), (2, Value::Scalar(7.0)), "{mode:?}");
        }
    }

    #[test]
    fn wait_any_take_consumes_exactly_one() {
        for mode in MODES {
            let s = ShardedStore::with_wake_mode(4, mode);
            s.put("a", Value::Scalar(1.0));
            s.put("b", Value::Scalar(2.0));
            let (i, _) = s.wait_any_take(&["a", "b"], Duration::from_secs(1)).unwrap();
            assert_eq!(i, 0, "{mode:?}");
            assert!(!s.exists("a"));
            assert!(s.exists("b"));
        }
    }

    #[test]
    fn wait_any_take_racing_waiters_split_the_values() {
        // Two consumers subscribe to the same 16-key set; every value is
        // delivered to exactly one of them (takes are exclusive).
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(8, mode));
            let names: Vec<String> = (0..16).map(|i| format!("k{i}")).collect();
            let mut consumers = Vec::new();
            for _ in 0..2 {
                let s = s.clone();
                let names = names.clone();
                consumers.push(std::thread::spawn(move || {
                    let keys: Vec<&str> = names.iter().map(|n| n.as_str()).collect();
                    let mut got = Vec::new();
                    for _ in 0..8 {
                        if let Some((i, _)) = s.wait_any_take(&keys, Duration::from_secs(10)) {
                            got.push(i);
                        }
                    }
                    got
                }));
            }
            let producer = {
                let s = s.clone();
                std::thread::spawn(move || {
                    for i in 0..16 {
                        s.put(&format!("k{i}"), Value::Scalar(i as f64));
                        std::thread::sleep(Duration::from_millis(1));
                    }
                })
            };
            producer.join().unwrap();
            let mut taken = Vec::new();
            for c in consumers {
                taken.extend(c.join().unwrap());
            }
            // 16 distinct values produced, 16 exclusive takes demanded:
            // every key is delivered exactly once across the consumers.
            taken.sort_unstable();
            assert_eq!(taken, (0..16).collect::<Vec<_>>(), "{mode:?}");
            assert!(s.is_empty());
        }
    }

    #[test]
    fn overlapping_waiter_sets_deliver_exactly_once() {
        // Lost-wakeup / double-delivery stress for the per-key path: 4
        // producers publish 64 distinct keys while 4 consumers subscribe
        // to OVERLAPPING key windows (every key covered by >= 2
        // consumers).  Every value must be taken exactly once.
        for mode in MODES {
            let n_keys = 64usize;
            let s = Arc::new(ShardedStore::with_wake_mode(8, mode));
            let names: Vec<String> = (0..n_keys).map(|i| format!("ov{i}")).collect();
            let names = Arc::new(names);
            let remaining = Arc::new(AtomicUsize::new(n_keys));

            let mut consumers = Vec::new();
            for c in 0..4 {
                let s = s.clone();
                let names = names.clone();
                let remaining = remaining.clone();
                consumers.push(std::thread::spawn(move || {
                    // Window of 32 keys starting at c*16, wrapping: each
                    // key lies in exactly two consumer windows.
                    let window: Vec<&str> = (0..32)
                        .map(|j| names[(c * 16 + j) % n_keys].as_str())
                        .collect();
                    let mut got: Vec<String> = Vec::new();
                    loop {
                        if remaining.load(Ordering::SeqCst) == 0 {
                            break;
                        }
                        if let Some((i, _)) =
                            s.wait_any_take(&window, Duration::from_millis(50))
                        {
                            remaining.fetch_sub(1, Ordering::SeqCst);
                            got.push(window[i].to_string());
                        }
                    }
                    got
                }));
            }
            let mut producers = Vec::new();
            for p in 0..4 {
                let s = s.clone();
                let names = names.clone();
                producers.push(std::thread::spawn(move || {
                    for i in 0..n_keys / 4 {
                        let k = p * (n_keys / 4) + i;
                        s.put(names[k].as_str(), Value::Scalar(k as f64));
                        if i % 5 == 0 {
                            std::thread::yield_now();
                        }
                    }
                }));
            }
            for p in producers {
                p.join().unwrap();
            }
            let mut all: Vec<String> = Vec::new();
            for c in consumers {
                all.extend(c.join().unwrap());
            }
            all.sort_unstable();
            let mut want: Vec<String> = names.iter().cloned().collect();
            want.sort_unstable();
            assert_eq!(all, want, "{mode:?}: every key delivered exactly once");
            assert!(s.is_empty());
        }
    }

    #[test]
    fn put_clear_race_delivers_at_most_once_and_never_hangs() {
        // A clearer races producers and takers over one small key set:
        // values may be destroyed by `clear` before delivery (at-most-
        // once), but nothing may be delivered twice and nobody may hang —
        // in both wakeup modes (the seq-lock baseline stays selectable
        // via hpc.db_seqlock_wake).
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(4, mode));
            let rounds = 200usize;
            let taken = Arc::new(AtomicUsize::new(0));
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

            let taker = {
                let s = s.clone();
                let taken = taken.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        if s.wait_any_take(&["r0", "r1"], Duration::from_millis(5)).is_some() {
                            taken.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                })
            };
            let clearer = {
                let s = s.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        s.clear();
                        std::thread::yield_now();
                    }
                })
            };
            for i in 0..rounds {
                s.put(if i % 2 == 0 { "r0" } else { "r1" }, Value::Scalar(i as f64));
            }
            // Give the taker a chance to drain what survived, then stop.
            std::thread::sleep(Duration::from_millis(30));
            stop.store(true, Ordering::SeqCst);
            taker.join().unwrap();
            clearer.join().unwrap();
            // Deliveries + survivors can never exceed what was produced.
            assert!(
                taken.load(Ordering::SeqCst) + s.len() <= rounds,
                "{mode:?}: delivered {} + stored {} > produced {rounds}",
                taken.load(Ordering::SeqCst),
                s.len()
            );
        }
    }

    #[test]
    fn waiter_slots_are_recycled() {
        let s = ShardedStore::new(4);
        // Parking subscriptions need a slot; repeated parks on one thread
        // reuse it (<= 1 because the thread-local cache may already hold
        // a slot from an earlier wait on this thread).
        for _ in 0..5 {
            assert!(s.wait_any(&["absent"], Duration::from_millis(5)).is_none());
        }
        let after_parks = s.stats().waiters_created;
        assert!(after_parks <= 1, "one thread needs at most one slot");
        for _ in 0..5 {
            assert!(s.wait_any(&["absent"], Duration::from_millis(5)).is_none());
        }
        assert_eq!(s.stats().waiters_created, after_parks);
        // Immediate hits take the lease-free fast path: no slot at all.
        for i in 0..50 {
            s.put("w", Value::Scalar(i as f64));
            assert!(s.wait_any_take(&["w", "other"], Duration::from_secs(1)).is_some());
        }
        assert_eq!(s.stats().waiters_created, after_parks);
    }

    #[test]
    fn clear_racing_a_waiter_wakes_then_times_out() {
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(4, mode));
            s.put("noise", Value::Scalar(0.0));
            let s2 = s.clone();
            let clearer = std::thread::spawn(move || {
                for _ in 0..50 {
                    s2.put("noise", Value::Scalar(1.0));
                    s2.clear();
                }
            });
            // The waiter's key never survives a clear; it must neither
            // hang nor panic, and must time out once the noise stops.
            let t0 = Instant::now();
            let got = s.wait_any(&["never"], Duration::from_millis(80));
            clearer.join().unwrap();
            assert!(got.is_none(), "{mode:?}");
            assert!(t0.elapsed() >= Duration::from_millis(75));
            // Same race for the single-key path.
            assert!(s.wait_for("never2", Duration::from_millis(30)).is_none());
        }
    }

    #[test]
    fn wait_any_timeout_holds_under_unrelated_traffic() {
        // Sustained puts on other keys must not starve the timeout (in
        // per-key mode they don't even wake the subscriber).
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(4, mode));
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let writer = {
                let s = s.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        s.put(&format!("noise{}", i % 64), Value::Scalar(i as f64));
                        i += 1;
                    }
                })
            };
            let t0 = Instant::now();
            let got = s.wait_any(&["absent1", "absent2"], Duration::from_millis(100));
            stop.store(true, Ordering::Relaxed);
            writer.join().unwrap();
            assert!(got.is_none(), "{mode:?}");
            assert!(t0.elapsed() >= Duration::from_millis(95));
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "{mode:?}: timeout starved by unrelated puts: {:?}",
                t0.elapsed()
            );
        }
    }

    #[test]
    fn wait_any_under_multithread_contention() {
        // N producers each publish a distinct key; one consumer drains
        // them all in arrival order via repeated wait_any_take.
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(8, mode));
            let n = 16usize;
            let mut producers = Vec::new();
            for i in 0..n {
                let s = s.clone();
                producers.push(std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis((i as u64 * 7) % 23));
                    s.put(&format!("p{i}"), Value::Scalar(i as f64));
                }));
            }
            let names: Vec<String> = (0..n).map(|i| format!("p{i}")).collect();
            let keys: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            let mut seen = vec![false; n];
            for _ in 0..n {
                let (i, v) = s
                    .wait_any_take(&keys, Duration::from_secs(10))
                    .expect("all producers publish");
                assert_eq!(v.as_scalar(), Some(i as f64));
                assert!(!seen[i], "{mode:?}: key p{i} delivered twice");
                seen[i] = true;
            }
            for p in producers {
                p.join().unwrap();
            }
            assert!(seen.iter().all(|&x| x));
            assert!(s.is_empty());
        }
    }

    #[test]
    fn subscription_delivers_preexisting_and_later_puts() {
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(4, mode));
            s.put("pre", Value::Scalar(1.0));
            let mut sub = Subscription::new(s.clone());
            sub.add(0, "pre"); // present at add time: self-delivered
            sub.add(7, "late");
            assert_eq!(sub.len(), 2);
            let (tag, v) = sub.wait_take(Duration::from_secs(1)).unwrap();
            assert_eq!((tag, v.as_scalar()), (0, Some(1.0)), "{mode:?}");
            assert!(!s.exists("pre"), "wait_take consumes");

            let s2 = s.clone();
            let h = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(15));
                s2.put("late", Value::Scalar(2.0));
            });
            let (tag, v) = sub.wait_take(Duration::from_secs(5)).unwrap();
            h.join().unwrap();
            assert_eq!((tag, v.as_scalar()), (7, Some(2.0)), "{mode:?}");
            // Nothing left: times out.
            assert!(sub.wait_take(Duration::from_millis(20)).is_none());
        }
    }

    #[test]
    fn subscription_incremental_updates_and_stale_deliveries() {
        let s = Arc::new(ShardedStore::new(4));
        let mut sub = Subscription::new(s.clone());
        sub.add(3, "a");
        s.put("a", Value::Scalar(1.0)); // queued delivery for tag 3
        sub.remove(3); // ...now stale
        assert!(sub.is_empty());
        assert!(
            sub.wait_take(Duration::from_millis(20)).is_none(),
            "stale delivery must be skipped, not returned"
        );
        assert!(s.exists("a"), "stale delivery must not consume the value");

        // Retargeting a tag honors deliveries against the NEW key only.
        sub.add(3, "b");
        s.put("b", Value::Scalar(2.0));
        let (tag, v) = sub.wait_take(Duration::from_secs(1)).unwrap();
        assert_eq!((tag, v.as_scalar()), (3, Some(2.0)));

        // Replace-on-add: one tag, one live registration.
        sub.add(0, "x");
        sub.add(0, "y");
        s.put("x", Value::Scalar(9.0));
        assert!(
            sub.wait_take(Duration::from_millis(20)).is_none(),
            "tag 0 was retargeted from x to y"
        );
        s.put("y", Value::Scalar(4.0));
        let (tag, v) = sub.wait_take(Duration::from_secs(1)).unwrap();
        assert_eq!((tag, v.as_scalar()), (0, Some(4.0)));
    }

    #[test]
    fn subscription_counts_registry_ops_and_drop_deregisters() {
        let s = Arc::new(ShardedStore::new(4));
        let base = s.stats().sub_ops;
        {
            let mut sub = Subscription::new(s.clone());
            sub.add(0, "k0"); // 1 op
            sub.add(1, "k1"); // 1 op
            sub.add(1, "k1b"); // remove + add = 2 ops
            sub.remove(0); // 1 op
            sub.remove(0); // no-op: tag already empty
            assert_eq!(s.stats().sub_ops - base, 5);
            // Waiting with queued deliveries costs zero registry ops.
            s.put("k1b", Value::Scalar(1.0));
            assert!(sub.wait_take(Duration::from_secs(1)).is_some());
            assert_eq!(s.stats().sub_ops - base, 5);
        } // drop deregisters the one live slot
        assert_eq!(s.stats().sub_ops - base, 6);
        // No dangling registration: a put after drop delivers nowhere
        // (would panic/leak otherwise; observable as clean clear()).
        s.put("k1b", Value::Scalar(2.0));
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn subscription_races_wait_any_takers_exactly_once() {
        // One persistent subscriber and one wait_any_take consumer split
        // a stream of puts over the same keys without loss or double
        // delivery.
        for mode in MODES {
            let s = Arc::new(ShardedStore::with_wake_mode(8, mode));
            let n = 32usize;
            let names: Vec<String> = (0..n).map(|i| format!("race{i}")).collect();
            let total = Arc::new(AtomicUsize::new(0));
            let rival = {
                let s = s.clone();
                let names = names.clone();
                let total = total.clone();
                std::thread::spawn(move || {
                    let keys: Vec<&str> = names.iter().map(|x| x.as_str()).collect();
                    let mut got = 0usize;
                    while total.load(Ordering::SeqCst) < n {
                        if s.wait_any_take(&keys, Duration::from_millis(10)).is_some() {
                            got += 1;
                            total.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    got
                })
            };
            let mut sub = Subscription::new(s.clone());
            for (i, name) in names.iter().enumerate() {
                sub.add(i, name.as_str());
            }
            let producer = {
                let s = s.clone();
                let names = names.clone();
                std::thread::spawn(move || {
                    for name in names.iter() {
                        s.put(name.as_str(), Value::Scalar(1.0));
                        std::thread::yield_now();
                    }
                })
            };
            let mut mine = 0usize;
            while total.load(Ordering::SeqCst) < n {
                if sub.wait_take(Duration::from_millis(10)).is_some() {
                    mine += 1;
                    total.fetch_add(1, Ordering::SeqCst);
                }
            }
            producer.join().unwrap();
            let rival_got = rival.join().unwrap();
            assert_eq!(mine + rival_got, n, "{mode:?}: exactly-once split");
            assert!(s.is_empty(), "{mode:?}");
        }
    }

    #[test]
    fn stats_track_bytes() {
        let s = ShardedStore::new(2);
        s.put("t", Value::tensor(vec![8], vec![0.0; 8]));
        s.get("t");
        let st = s.stats();
        assert_eq!(st.bytes_in, 8 + 32);
        assert_eq!(st.bytes_out, 8 + 32);
        assert_eq!(st.hits, 1);
    }
}
