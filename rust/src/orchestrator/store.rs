//! The in-memory datastore backing the orchestrator.
//!
//! Two backends mirror the paper's observation (§3.1) that swapping Redis
//! for its multithreaded fork KeyDB "provided significantly more
//! performance":
//!
//! * [`ShardedStore`] — N independently locked shards (KeyDB analogue):
//!   concurrent clients hitting different keys proceed in parallel.
//! * a 1-shard store — every operation serializes on one lock, the
//!   single-threaded-Redis analogue.
//!
//! Blocking reads come in two shapes, both condvar-backed (no
//! spin-polling): single-key ([`ShardedStore::wait_for`] /
//! [`ShardedStore::wait_take`], the SmartRedis `poll_tensor` analogue)
//! and multi-key ([`ShardedStore::wait_any`] /
//! [`ShardedStore::wait_any_take`]), the arrival-order subscription the
//! event-driven rollout collector consumes env states through.
//!
//! `bench_db` regenerates the comparison (experiment A1 in DESIGN.md §6).

use super::value::Value;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Operation counters (throughput metrics for the §Perf pass).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub hits: AtomicU64,
    pub poll_misses: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
}

/// Snapshot of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub hits: u64,
    pub poll_misses: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

struct Shard {
    map: Mutex<HashMap<String, Value>>,
    cv: Condvar,
}

/// Store-wide notifier for multi-key subscriptions ([`ShardedStore::wait_any`]).
///
/// Single-key waiters park on their shard's condvar, but a multi-key waiter
/// may span shards, so it parks on this store-level sequence lock instead:
/// every mutation that could satisfy a subscription bumps `seq` and wakes
/// all subscribers, which then re-scan their key set.  The `waiters` count
/// keeps the common case (no multi-key waiter) free of the extra lock.
#[derive(Default)]
struct MultiWait {
    seq: Mutex<u64>,
    cv: Condvar,
    waiters: AtomicUsize,
}

impl MultiWait {
    fn bump(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut seq = self.seq.lock().unwrap();
        *seq = seq.wrapping_add(1);
        self.cv.notify_all();
    }
}

/// Decrements the subscriber count on every exit path of `wait_any`.
struct WaiterGuard<'a>(&'a AtomicUsize);

impl Drop for WaiterGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Sharded in-memory key-value store.
pub struct ShardedStore {
    shards: Vec<Shard>,
    multi: MultiWait,
    stats: StoreStats,
}

fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl ShardedStore {
    /// Create a store with `shards` independent locks (1 = Redis-like).
    pub fn new(shards: usize) -> ShardedStore {
        assert!(shards >= 1);
        ShardedStore {
            shards: (0..shards)
                .map(|_| Shard {
                    map: Mutex::new(HashMap::new()),
                    cv: Condvar::new(),
                })
                .collect(),
            multi: MultiWait::default(),
            stats: StoreStats::default(),
        }
    }

    fn shard(&self, key: &str) -> &Shard {
        let i = (fnv1a(key) as usize) % self.shards.len();
        &self.shards[i]
    }

    /// Number of shards (1 = single-lock backend).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Store a value under a key (overwrites), waking pollers.
    pub fn put(&self, key: &str, value: Value) {
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_in
            .fetch_add(value.size_bytes() as u64, Ordering::Relaxed);
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        map.insert(key.to_string(), value);
        shard.cv.notify_all();
        drop(map);
        self.multi.bump();
    }

    /// Fetch a clone of the value, if present.
    pub fn get(&self, key: &str) -> Option<Value> {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard(key);
        let map = shard.map.lock().unwrap();
        let v = map.get(key).cloned();
        if let Some(ref val) = v {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_out
                .fetch_add(val.size_bytes() as u64, Ordering::Relaxed);
        }
        v
    }

    /// Atomically fetch and remove (consume a message).
    pub fn take(&self, key: &str) -> Option<Value> {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        let v = map.remove(key);
        if let Some(ref val) = v {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_out
                .fetch_add(val.size_bytes() as u64, Ordering::Relaxed);
        }
        v
    }

    /// Does the key exist?
    pub fn exists(&self, key: &str) -> bool {
        self.shard(key).map.lock().unwrap().contains_key(key)
    }

    /// Remove a key; true if it existed.
    pub fn delete(&self, key: &str) -> bool {
        self.shard(key).map.lock().unwrap().remove(key).is_some()
    }

    /// Remove everything (between training iterations).  Waiters (both
    /// single-key and multi-key) are woken so they re-check and, finding
    /// their keys gone, go back to waiting until their timeout.
    pub fn clear(&self) {
        for s in &self.shards {
            s.map.lock().unwrap().clear();
            s.cv.notify_all();
        }
        self.multi.bump();
    }

    /// Total number of stored keys.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.map.lock().unwrap().len()).sum()
    }

    /// True if no keys are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking poll: wait until `key` appears (condvar-backed, the
    /// SmartRedis `poll_tensor` analogue) or `timeout` elapses.
    pub fn wait_for(&self, key: &str, timeout: Duration) -> Option<Value> {
        let deadline = Instant::now() + timeout;
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        loop {
            if let Some(v) = map.get(key) {
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .bytes_out
                    .fetch_add(v.size_bytes() as u64, Ordering::Relaxed);
                return Some(v.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let (m, res) = shard.cv.wait_timeout(map, deadline - now).unwrap();
            map = m;
            if res.timed_out() && !map.contains_key(key) {
                return None;
            }
        }
    }

    /// Blocking poll-and-take: wait until `key` appears, then consume it.
    pub fn wait_take(&self, key: &str, timeout: Duration) -> Option<Value> {
        let deadline = Instant::now() + timeout;
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        loop {
            if let Some(v) = map.remove(key) {
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .bytes_out
                    .fetch_add(v.size_bytes() as u64, Ordering::Relaxed);
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let (m, res) = shard.cv.wait_timeout(map, deadline - now).unwrap();
            map = m;
            if res.timed_out() && !map.contains_key(key) {
                return None;
            }
        }
    }

    /// Blocking multi-key subscription: wait until **any** of `keys`
    /// appears and return `(index, value)` for the first one found
    /// (scanning in argument order, so earlier keys win ties).  Returns
    /// `None` on timeout.
    ///
    /// This is the arrival-order primitive behind the event-driven rollout
    /// collector: instead of blocking on one env's state while others sit
    /// ready (the per-key `poll` pattern whose synchronization overhead
    /// paper §6.2 measures), the trainer subscribes to every outstanding
    /// key at once and is woken by whichever env finishes first.
    /// Condvar-backed — no spin-polling.
    pub fn wait_any(&self, keys: &[&str], timeout: Duration) -> Option<(usize, Value)> {
        self.wait_any_impl(keys, timeout, false)
    }

    /// Like [`ShardedStore::wait_any`], but atomically consumes the value
    /// it returns (at most one key is removed per call).
    pub fn wait_any_take(&self, keys: &[&str], timeout: Duration) -> Option<(usize, Value)> {
        self.wait_any_impl(keys, timeout, true)
    }

    fn wait_any_impl(
        &self,
        keys: &[&str],
        timeout: Duration,
        take: bool,
    ) -> Option<(usize, Value)> {
        if keys.is_empty() {
            return None;
        }
        let deadline = Instant::now() + timeout;
        // Register before the first scan: a put that misses the waiter
        // count must have completed its insert already, so the scan below
        // observes the key; a put that sees the count bumps `seq`.
        self.multi.waiters.fetch_add(1, Ordering::SeqCst);
        let _guard = WaiterGuard(&self.multi.waiters);
        loop {
            // Snapshot the sequence BEFORE scanning: a put landing during
            // the scan advances it and turns the wait below into a rescan.
            let seq0 = *self.multi.seq.lock().unwrap();
            for (i, key) in keys.iter().enumerate() {
                let hit = if take { self.take(key) } else { self.get(key) };
                if let Some(v) = hit {
                    return Some((i, v));
                }
            }
            // Re-check the deadline after every scan: sustained puts on
            // unrelated keys keep advancing `seq`, and without this the
            // rescan loop would never consult the timeout.
            if Instant::now() >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let mut seq = self.multi.seq.lock().unwrap();
            while *seq == seq0 {
                let now = Instant::now();
                if now >= deadline {
                    return None;
                }
                let (s, res) = self
                    .multi
                    .cv
                    .wait_timeout(seq, deadline - now)
                    .unwrap();
                seq = s;
                if res.timed_out() && *seq == seq0 {
                    return None;
                }
            }
        }
    }

    /// Snapshot the op counters.
    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            puts: self.stats.puts.load(Ordering::Relaxed),
            gets: self.stats.gets.load(Ordering::Relaxed),
            hits: self.stats.hits.load(Ordering::Relaxed),
            poll_misses: self.stats.poll_misses.load(Ordering::Relaxed),
            bytes_in: self.stats.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.stats.bytes_out.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn put_get_take() {
        let s = ShardedStore::new(4);
        s.put("a", Value::Scalar(1.5));
        assert_eq!(s.get("a"), Some(Value::Scalar(1.5)));
        assert_eq!(s.take("a"), Some(Value::Scalar(1.5)));
        assert_eq!(s.get("a"), None);
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_and_delete() {
        let s = ShardedStore::new(2);
        s.put("k", Value::Flag(false));
        s.put("k", Value::Flag(true));
        assert_eq!(s.get("k").unwrap().as_flag(), Some(true));
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
    }

    #[test]
    fn wait_for_times_out() {
        let s = ShardedStore::new(1);
        let t0 = Instant::now();
        assert!(s.wait_for("nope", Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn wait_for_sees_concurrent_put() {
        let s = Arc::new(ShardedStore::new(4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.put("late", Value::Scalar(7.0));
        });
        let v = s.wait_for("late", Duration::from_secs(2));
        h.join().unwrap();
        assert_eq!(v, Some(Value::Scalar(7.0)));
    }

    #[test]
    fn wait_take_consumes() {
        let s = Arc::new(ShardedStore::new(4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.put("x", Value::Scalar(1.0));
        });
        assert!(s.wait_take("x", Duration::from_secs(2)).is_some());
        h.join().unwrap();
        assert!(!s.exists("x"));
    }

    #[test]
    fn concurrent_clients_consistent() {
        let s = Arc::new(ShardedStore::new(8));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s.put(&format!("t{t}:k{i}"), Value::Scalar(i as f64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 800);
        let st = s.stats();
        assert_eq!(st.puts, 800);
        for t in 0..8 {
            for i in (0..100).step_by(17) {
                assert_eq!(
                    s.get(&format!("t{t}:k{i}")).unwrap().as_scalar(),
                    Some(i as f64)
                );
            }
        }
    }

    #[test]
    fn wait_any_returns_existing_key_with_priority() {
        let s = ShardedStore::new(4);
        s.put("b", Value::Scalar(2.0));
        s.put("a", Value::Scalar(1.0));
        // Argument order, not insertion order, breaks the tie.
        let (i, v) = s
            .wait_any(&["a", "b"], Duration::from_secs(1))
            .expect("both present");
        assert_eq!((i, v), (0, Value::Scalar(1.0)));
        // Non-consuming: both keys still there.
        assert!(s.exists("a") && s.exists("b"));
    }

    #[test]
    fn wait_any_times_out_empty_and_missing() {
        let s = ShardedStore::new(2);
        assert!(s.wait_any(&[], Duration::from_secs(5)).is_none());
        let t0 = Instant::now();
        assert!(s
            .wait_any(&["x", "y"], Duration::from_millis(30))
            .is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert!(t0.elapsed() < Duration::from_secs(4));
    }

    #[test]
    fn wait_any_sees_concurrent_put_on_any_key() {
        let s = Arc::new(ShardedStore::new(8));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.put("k7", Value::Scalar(7.0));
        });
        let (i, v) = s
            .wait_any(&["k3", "k5", "k7"], Duration::from_secs(5))
            .expect("concurrent put must wake the waiter");
        h.join().unwrap();
        assert_eq!((i, v), (2, Value::Scalar(7.0)));
    }

    #[test]
    fn wait_any_take_consumes_exactly_one() {
        let s = ShardedStore::new(4);
        s.put("a", Value::Scalar(1.0));
        s.put("b", Value::Scalar(2.0));
        let (i, _) = s.wait_any_take(&["a", "b"], Duration::from_secs(1)).unwrap();
        assert_eq!(i, 0);
        assert!(!s.exists("a"));
        assert!(s.exists("b"));
    }

    #[test]
    fn wait_any_take_racing_waiters_split_the_values() {
        // Two consumers subscribe to the same 16-key set; every value is
        // delivered to exactly one of them (takes are exclusive).
        let s = Arc::new(ShardedStore::new(8));
        let names: Vec<String> = (0..16).map(|i| format!("k{i}")).collect();
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let s = s.clone();
            let names = names.clone();
            consumers.push(std::thread::spawn(move || {
                let keys: Vec<&str> = names.iter().map(|n| n.as_str()).collect();
                let mut got = Vec::new();
                for _ in 0..8 {
                    if let Some((i, _)) = s.wait_any_take(&keys, Duration::from_secs(10)) {
                        got.push(i);
                    }
                }
                got
            }));
        }
        let producer = {
            let s = s.clone();
            std::thread::spawn(move || {
                for i in 0..16 {
                    s.put(&format!("k{i}"), Value::Scalar(i as f64));
                    std::thread::sleep(Duration::from_millis(1));
                }
            })
        };
        producer.join().unwrap();
        let mut taken = Vec::new();
        for c in consumers {
            taken.extend(c.join().unwrap());
        }
        // 16 distinct values produced, 16 exclusive takes demanded: every
        // key is delivered exactly once across the two consumers.
        taken.sort_unstable();
        assert_eq!(taken, (0..16).collect::<Vec<_>>());
        assert!(s.is_empty());
    }

    #[test]
    fn clear_racing_a_waiter_wakes_then_times_out() {
        let s = Arc::new(ShardedStore::new(4));
        s.put("noise", Value::Scalar(0.0));
        let s2 = s.clone();
        let clearer = std::thread::spawn(move || {
            for _ in 0..50 {
                s2.put("noise", Value::Scalar(1.0));
                s2.clear();
            }
        });
        // The waiter's key never survives a clear; it must neither hang
        // nor panic, and must time out once the noise stops.
        let t0 = Instant::now();
        let got = s.wait_any(&["never"], Duration::from_millis(80));
        clearer.join().unwrap();
        assert!(got.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(75));
        // Same race for the single-key path.
        assert!(s.wait_for("never2", Duration::from_millis(30)).is_none());
    }

    #[test]
    fn wait_any_timeout_holds_under_unrelated_traffic() {
        // Sustained puts on other keys keep waking the subscriber; the
        // timeout must still be honored (bounded overshoot).
        let s = Arc::new(ShardedStore::new(4));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let s = s.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    s.put(&format!("noise{}", i % 64), Value::Scalar(i as f64));
                    i += 1;
                }
            })
        };
        let t0 = Instant::now();
        let got = s.wait_any(&["absent1", "absent2"], Duration::from_millis(100));
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
        assert!(got.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(95));
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timeout starved by unrelated puts: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn wait_any_under_multithread_contention() {
        // N producers each publish a distinct key; one consumer drains
        // them all in arrival order via repeated wait_any_take.
        let s = Arc::new(ShardedStore::new(8));
        let n = 16usize;
        let mut producers = Vec::new();
        for i in 0..n {
            let s = s.clone();
            producers.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis((i as u64 * 7) % 23));
                s.put(&format!("p{i}"), Value::Scalar(i as f64));
            }));
        }
        let names: Vec<String> = (0..n).map(|i| format!("p{i}")).collect();
        let keys: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut seen = vec![false; n];
        for _ in 0..n {
            let (i, v) = s
                .wait_any_take(&keys, Duration::from_secs(10))
                .expect("all producers publish");
            assert_eq!(v.as_scalar(), Some(i as f64));
            assert!(!seen[i], "key p{i} delivered twice");
            seen[i] = true;
        }
        for p in producers {
            p.join().unwrap();
        }
        assert!(seen.iter().all(|&x| x));
        assert!(s.is_empty());
    }

    #[test]
    fn stats_track_bytes() {
        let s = ShardedStore::new(2);
        s.put("t", Value::tensor(vec![8], vec![0.0; 8]));
        s.get("t");
        let st = s.stats();
        assert_eq!(st.bytes_in, 8 + 32);
        assert_eq!(st.bytes_out, 8 + 32);
        assert_eq!(st.hits, 1);
    }
}
