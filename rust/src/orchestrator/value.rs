//! Values stored in the orchestrator: tensors (flow states, actions),
//! scalars and flags (the done-flag protocol of paper §3.1).

/// A value in the in-memory datastore.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Dense f32 tensor with shape (the SmartRedis `put_tensor` analogue).
    Tensor { shape: Vec<usize>, data: Vec<f32> },
    /// Scalar (timings, rewards).
    Scalar(f64),
    /// Boolean flag ("FLEXI has reached its final state and will terminate").
    Flag(bool),
    /// Opaque bytes (checkpoints, metadata).
    Bytes(Vec<u8>),
}

impl Value {
    /// Build a tensor value; panics if shape and data disagree.
    pub fn tensor(shape: Vec<usize>, data: Vec<f32>) -> Value {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "tensor shape {shape:?} != data len {}", data.len());
        Value::Tensor { shape, data }
    }

    /// Approximate payload size in bytes (for the throughput metrics).
    pub fn size_bytes(&self) -> usize {
        match self {
            Value::Tensor { shape, data } => shape.len() * 8 + data.len() * 4,
            Value::Scalar(_) => 8,
            Value::Flag(_) => 1,
            Value::Bytes(b) => b.len(),
        }
    }

    /// Tensor accessor.
    pub fn as_tensor(&self) -> Option<(&[usize], &[f32])> {
        match self {
            Value::Tensor { shape, data } => Some((shape, data)),
            _ => None,
        }
    }

    /// Flag accessor.
    pub fn as_flag(&self) -> Option<bool> {
        match self {
            Value::Flag(b) => Some(*b),
            _ => None,
        }
    }

    /// Scalar accessor.
    pub fn as_scalar(&self) -> Option<f64> {
        match self {
            Value::Scalar(x) => Some(*x),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_construction_checks_shape() {
        let v = Value::tensor(vec![2, 3], vec![0.0; 6]);
        let (shape, data) = v.as_tensor().unwrap();
        assert_eq!(shape, &[2, 3]);
        assert_eq!(data.len(), 6);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Value::tensor(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn size_accounting() {
        assert_eq!(Value::Scalar(1.0).size_bytes(), 8);
        assert_eq!(Value::Flag(true).size_bytes(), 1);
        assert_eq!(Value::tensor(vec![4], vec![0.0; 4]).size_bytes(), 8 + 16);
    }

    #[test]
    fn accessors_reject_wrong_kind() {
        assert!(Value::Scalar(1.0).as_tensor().is_none());
        assert!(Value::Flag(true).as_scalar().is_none());
        assert_eq!(Value::Flag(true).as_flag(), Some(true));
    }
}
