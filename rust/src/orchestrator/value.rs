//! Values stored in the orchestrator: tensors (flow states, actions),
//! scalars and flags (the done-flag protocol of paper §3.1).
//!
//! Tensor and byte payloads are reference-counted (`Arc<[f32]>` /
//! `Arc<[u8]>`): a `Value` clone — and therefore a store `get` or a
//! multi-key subscription hit — is a refcount bump, never a deep copy of
//! the 48³-scale state tensor.  Producers that own an `Arc` buffer can
//! republish it through [`crate::orchestrator::Client::put_tensor_shared`]
//! without copying; [`TensorPool`] recycles such buffers so the
//! steady-state rollout exchange allocates nothing.

use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Hard cap on any single wire payload (tensor data, byte blobs, whole
/// frames).  A remote peer that announces a length beyond this is
/// malformed or hostile; decoders reject it instead of allocating.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Little-endian primitive readers/writers shared by the [`Value`] codec
/// and the transport frame codec ([`crate::orchestrator::transport`]).
/// Readers never panic: every bounds problem is a recoverable `Err`.
pub(crate) mod wire {
    use anyhow::{ensure, Result};

    pub fn w_u16(out: &mut Vec<u8>, v: u16) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    pub fn w_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    pub fn w_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    pub fn w_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    pub fn w_str(out: &mut Vec<u8>, s: &str) {
        assert!(s.len() <= u16::MAX as usize, "key too long for the wire: {}", s.len());
        w_u16(out, s.len() as u16);
        out.extend_from_slice(s.as_bytes());
    }

    pub fn r_bytes<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= buf.len() && *pos <= buf.len() - n,
            "truncated frame: need {n} bytes at offset {pos}, have {}",
            buf.len()
        );
        let out = &buf[*pos..*pos + n];
        *pos += n;
        Ok(out)
    }
    pub fn r_u8(buf: &[u8], pos: &mut usize) -> Result<u8> {
        Ok(r_bytes(buf, pos, 1)?[0])
    }
    pub fn r_u16(buf: &[u8], pos: &mut usize) -> Result<u16> {
        Ok(u16::from_le_bytes(r_bytes(buf, pos, 2)?.try_into().unwrap()))
    }
    pub fn r_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
        Ok(u32::from_le_bytes(r_bytes(buf, pos, 4)?.try_into().unwrap()))
    }
    pub fn r_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
        Ok(u64::from_le_bytes(r_bytes(buf, pos, 8)?.try_into().unwrap()))
    }
    pub fn r_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
        Ok(f64::from_le_bytes(r_bytes(buf, pos, 8)?.try_into().unwrap()))
    }
    pub fn r_str(buf: &[u8], pos: &mut usize) -> Result<String> {
        let n = r_u16(buf, pos)? as usize;
        let raw = r_bytes(buf, pos, n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|e| anyhow::anyhow!("key is not utf-8: {e}"))?
            .to_string())
    }
}

/// A value in the in-memory datastore.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Dense f32 tensor with shape (the SmartRedis `put_tensor` analogue).
    /// Payload is shared: cloning the value bumps a refcount.
    Tensor {
        shape: Arc<[usize]>,
        data: Arc<[f32]>,
    },
    /// Scalar (timings, rewards).
    Scalar(f64),
    /// Boolean flag ("FLEXI has reached its final state and will terminate").
    Flag(bool),
    /// Opaque bytes (checkpoints, metadata); shared like tensor data.
    Bytes(Arc<[u8]>),
}

impl Value {
    /// Build a tensor value from owned vectors; panics if shape and data
    /// disagree.  The vectors are moved into shared buffers once here —
    /// every later clone is free.
    pub fn tensor(shape: Vec<usize>, data: Vec<f32>) -> Value {
        Value::tensor_shared(Arc::from(shape), Arc::from(data))
    }

    /// Build a tensor value from already-shared buffers (zero-copy
    /// republish of a producer-owned buffer); panics if shape and data
    /// disagree.
    pub fn tensor_shared(shape: Arc<[usize]>, data: Arc<[f32]>) -> Value {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "tensor shape {shape:?} != data len {}", data.len());
        Value::Tensor { shape, data }
    }

    /// Build a bytes value.
    pub fn bytes(data: Vec<u8>) -> Value {
        Value::Bytes(Arc::from(data))
    }

    /// Approximate payload size in bytes (for the throughput metrics).
    pub fn size_bytes(&self) -> usize {
        match self {
            Value::Tensor { shape, data } => shape.len() * 8 + data.len() * 4,
            Value::Scalar(_) => 8,
            Value::Flag(_) => 1,
            Value::Bytes(b) => b.len(),
        }
    }

    /// Tensor accessor.
    pub fn as_tensor(&self) -> Option<(&[usize], &[f32])> {
        match self {
            Value::Tensor { shape, data } => Some((shape, data)),
            _ => None,
        }
    }

    /// The shared tensor payload (refcount handle, no copy).
    pub fn tensor_data(&self) -> Option<Arc<[f32]>> {
        match self {
            Value::Tensor { data, .. } => Some(data.clone()),
            _ => None,
        }
    }

    /// Flag accessor.
    pub fn as_flag(&self) -> Option<bool> {
        match self {
            Value::Flag(b) => Some(*b),
            _ => None,
        }
    }

    /// Scalar accessor.
    pub fn as_scalar(&self) -> Option<f64> {
        match self {
            Value::Scalar(x) => Some(*x),
            _ => None,
        }
    }

    /// Serialize for the transport wire (little-endian, self-describing
    /// tag byte).  Layout:
    ///
    /// ```text
    /// Tensor: 0x00 | u8 ndim | ndim x u32 dim | u32 count | count x f32
    /// Scalar: 0x01 | f64
    /// Flag:   0x02 | u8 (0|1)
    /// Bytes:  0x03 | u32 len | len bytes
    /// ```
    ///
    /// The tensor element count is redundant with the dims product;
    /// [`Value::decode_from`] cross-checks them so a corrupted frame
    /// cannot reach the `tensor_shared` shape assertion.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        use wire::*;
        match self {
            Value::Tensor { shape, data } => {
                assert!(shape.len() <= u8::MAX as usize, "tensor rank {} too high", shape.len());
                out.push(0);
                out.push(shape.len() as u8);
                for &d in shape.iter() {
                    w_u32(out, u32::try_from(d).expect("tensor dim exceeds u32"));
                }
                w_u32(out, u32::try_from(data.len()).expect("tensor len exceeds u32"));
                for &x in data.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Value::Scalar(x) => {
                out.push(1);
                w_f64(out, *x);
            }
            Value::Flag(b) => {
                out.push(2);
                out.push(*b as u8);
            }
            Value::Bytes(b) => {
                assert!(b.len() <= MAX_PAYLOAD, "byte payload {} exceeds MAX_PAYLOAD", b.len());
                out.push(3);
                w_u32(out, b.len() as u32);
                out.extend_from_slice(b);
            }
        }
    }

    /// Decode one value from `buf` at `*pos`, advancing `*pos` past it.
    /// Malformed input — unknown tag, truncated payload, dims/count
    /// mismatch, oversized length — is an `Err`, never a panic.
    pub fn decode_from(buf: &[u8], pos: &mut usize) -> Result<Value> {
        use wire::*;
        match r_u8(buf, pos)? {
            0 => {
                let ndim = r_u8(buf, pos)? as usize;
                let mut shape = Vec::with_capacity(ndim);
                let mut product: usize = 1;
                for _ in 0..ndim {
                    let d = r_u32(buf, pos)? as usize;
                    product = product
                        .checked_mul(d)
                        .ok_or_else(|| anyhow::anyhow!("tensor dims overflow"))?;
                    shape.push(d);
                }
                let count = r_u32(buf, pos)? as usize;
                anyhow::ensure!(
                    count == product,
                    "tensor count {count} disagrees with dims product {product}"
                );
                anyhow::ensure!(
                    count.saturating_mul(4) <= MAX_PAYLOAD,
                    "tensor payload {count} floats exceeds MAX_PAYLOAD"
                );
                let raw = r_bytes(buf, pos, count * 4)?;
                let mut data = Vec::with_capacity(count);
                for c in raw.chunks_exact(4) {
                    data.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                Ok(Value::Tensor {
                    shape: Arc::from(shape),
                    data: Arc::from(data),
                })
            }
            1 => Ok(Value::Scalar(r_f64(buf, pos)?)),
            2 => match r_u8(buf, pos)? {
                0 => Ok(Value::Flag(false)),
                1 => Ok(Value::Flag(true)),
                other => anyhow::bail!("flag byte must be 0|1, got {other}"),
            },
            3 => {
                let n = r_u32(buf, pos)? as usize;
                anyhow::ensure!(n <= MAX_PAYLOAD, "byte payload {n} exceeds MAX_PAYLOAD");
                Ok(Value::bytes(r_bytes(buf, pos, n)?.to_vec()))
            }
            other => anyhow::bail!("unknown value tag {other}"),
        }
    }
}

/// Recycling pool of shared tensor payload buffers.
///
/// The rollout exchange publishes one state tensor per env per step and
/// one action tensor back; with `Arc` payloads the consumers only bump
/// refcounts, so the producer's handle becomes uniquely owned again as
/// soon as every consumer has dropped theirs — at which point the buffer
/// can be refilled in place instead of allocating a fresh one.
///
/// The pool is a FIFO queue: handles come back in publish order, so the
/// front is always the oldest buffer — the first whose consumers release
/// it.  One `strong_count` probe per take (never a scan past still-shared
/// buffers): a pool sized by one iteration's publishes hits the front
/// every time in steady state.  Designed for the exchange pattern of one
/// buffer length per pool; a mis-sized unique front is dropped and
/// reallocated rather than searched around.
///
/// `allocs` counts pool misses (fresh heap allocations): in a
/// steady-state training iteration it must not advance, which the envpool
/// integration test asserts.
pub struct TensorPool {
    free: VecDeque<Arc<[f32]>>,
    allocs: Arc<AtomicU64>,
    /// Parking bound: `put_back` beyond it drops the handle instead
    /// (safe — consumers keep the buffer alive until they finish), so a
    /// caller that retains published buffers indefinitely (a replay
    /// buffer, say) cannot grow the pool without bound.
    max_parked: usize,
}

impl TensorPool {
    /// A pool reporting its fresh allocations into `allocs` (shared so
    /// several pools — per-worker obs pools, the trainer's action pool —
    /// can aggregate into one exchange-path counter).  Size `max_parked`
    /// to the working set of one iteration: parking beyond it drops
    /// handles instead of growing the queue.
    pub fn new(allocs: Arc<AtomicU64>, max_parked: usize) -> TensorPool {
        TensorPool {
            free: VecDeque::new(),
            allocs,
            max_parked,
        }
    }

    /// Take a buffer of `len` floats with unique ownership
    /// (`Arc::get_mut` is guaranteed to succeed).  Reuses the oldest
    /// returned buffer if its consumers have all dropped their handles;
    /// allocates (and counts) otherwise.
    pub fn take_free(&mut self, len: usize) -> Arc<[f32]> {
        if self
            .free
            .front()
            .is_some_and(|b| Arc::strong_count(b) == 1)
        {
            let buf = self.free.pop_front().unwrap();
            if buf.len() == len {
                return buf;
            }
            // Unique but mis-sized (pool repurposed): drop and reallocate.
        }
        self.allocs.fetch_add(1, Ordering::Relaxed);
        Arc::from(vec![0f32; len])
    }

    /// Return the producer's handle after publishing clones of it.  The
    /// buffer becomes reusable once all published clones are dropped.
    /// Beyond `max_parked` the handle is dropped instead of parked (the
    /// consumers' clones keep the buffer alive; the pool just forgets
    /// it), bounding pool memory under pathological retention.
    pub fn put_back(&mut self, buf: Arc<[f32]>) {
        if self.free.len() < self.max_parked {
            self.free.push_back(buf);
        }
    }

    /// Buffers currently parked in the pool (free or still shared).
    pub fn parked(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_construction_checks_shape() {
        let v = Value::tensor(vec![2, 3], vec![0.0; 6]);
        let (shape, data) = v.as_tensor().unwrap();
        assert_eq!(shape, &[2, 3]);
        assert_eq!(data.len(), 6);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Value::tensor(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn size_accounting() {
        assert_eq!(Value::Scalar(1.0).size_bytes(), 8);
        assert_eq!(Value::Flag(true).size_bytes(), 1);
        assert_eq!(Value::tensor(vec![4], vec![0.0; 4]).size_bytes(), 8 + 16);
    }

    #[test]
    fn accessors_reject_wrong_kind() {
        assert!(Value::Scalar(1.0).as_tensor().is_none());
        assert!(Value::Flag(true).as_scalar().is_none());
        assert_eq!(Value::Flag(true).as_flag(), Some(true));
        assert!(Value::Scalar(1.0).tensor_data().is_none());
    }

    #[test]
    fn clone_is_refcount_bump_not_deep_copy() {
        let data: Arc<[f32]> = Arc::from(vec![1.0f32; 48 * 48 * 48 * 3]);
        let v = Value::tensor_shared(Arc::from(vec![data.len()]), data.clone());
        let c = v.clone();
        let d1 = v.tensor_data().unwrap();
        let d2 = c.tensor_data().unwrap();
        assert!(Arc::ptr_eq(&d1, &d2), "clone must share the payload");
        assert!(Arc::ptr_eq(&d1, &data), "value must share the producer's buffer");
    }

    #[test]
    fn pool_reuses_released_buffers_and_counts_misses() {
        let allocs = Arc::new(AtomicU64::new(0));
        let mut pool = TensorPool::new(allocs.clone(), 64);

        let mut a = pool.take_free(16);
        assert_eq!(allocs.load(Ordering::Relaxed), 1);
        Arc::get_mut(&mut a).unwrap()[0] = 3.0;
        let consumer = a.clone();
        pool.put_back(a);

        // Consumer still holds the front buffer: the pool must not hand
        // it out.
        let b = pool.take_free(16);
        assert_eq!(allocs.load(Ordering::Relaxed), 2);
        drop(consumer);
        pool.put_back(b);

        // Both buffers are free now (FIFO order a, b): two takes, zero
        // new allocations.
        let c = pool.take_free(16);
        let d = pool.take_free(16);
        assert_eq!(allocs.load(Ordering::Relaxed), 2);
        assert_eq!(c[0], 3.0, "oldest buffer comes back first");

        // Empty pool is a miss.
        let e = pool.take_free(8);
        assert_eq!(e.len(), 8);
        assert_eq!(allocs.load(Ordering::Relaxed), 3);
        drop((c, d));

        // A unique front of the wrong size is dropped and reallocated,
        // not searched around.
        pool.put_back(e);
        let f = pool.take_free(16);
        assert_eq!(f.len(), 16);
        assert_eq!(allocs.load(Ordering::Relaxed), 4);
        assert_eq!(pool.parked(), 0, "mis-sized front was evicted");
    }

    #[test]
    fn pool_unique_ownership_is_writable() {
        let mut pool = TensorPool::new(Arc::new(AtomicU64::new(0)), 64);
        let mut a = pool.take_free(4);
        Arc::get_mut(&mut a).expect("fresh buffer is unique")[3] = 7.0;
        pool.put_back(a.clone());
        drop(a);
        let mut b = pool.take_free(4);
        assert_eq!(b[3], 7.0, "recycled buffer keeps its storage");
        Arc::get_mut(&mut b).expect("recycled buffer is unique again");
    }

    #[test]
    fn wire_round_trip_every_variant() {
        let vals = [
            Value::tensor(vec![2, 3], vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]),
            Value::tensor(vec![0], vec![]),
            Value::Scalar(-0.125),
            Value::Flag(true),
            Value::Flag(false),
            Value::bytes(vec![0, 255, 7, 7]),
            Value::bytes(vec![]),
        ];
        for v in vals {
            let mut buf = vec![0xAB]; // prefix survives
            v.encode_into(&mut buf);
            let mut pos = 1;
            let back = Value::decode_from(&buf, &mut pos).unwrap();
            assert_eq!(back, v);
            assert_eq!(pos, buf.len(), "decode consumed the whole encoding");
        }
    }

    #[test]
    fn wire_decode_rejects_malformed_input_without_panicking() {
        // Truncations of a valid encoding at every split point.
        let mut full = Vec::new();
        Value::tensor(vec![2, 2], vec![1.0; 4]).encode_into(&mut full);
        for cut in 0..full.len() {
            let mut pos = 0;
            assert!(Value::decode_from(&full[..cut], &mut pos).is_err(), "cut at {cut}");
        }
        // Unknown tag.
        assert!(Value::decode_from(&[9], &mut 0).is_err());
        // Flag byte out of range.
        assert!(Value::decode_from(&[2, 3], &mut 0).is_err());
        // Tensor count disagreeing with dims product.
        let mut bad = vec![0u8, 1]; // ndim 1
        bad.extend_from_slice(&2u32.to_le_bytes()); // dim 2
        bad.extend_from_slice(&3u32.to_le_bytes()); // count 3 != 2
        bad.extend_from_slice(&[0; 12]);
        assert!(Value::decode_from(&bad, &mut 0).is_err());
        // Oversized byte length never allocates.
        let mut huge = vec![3u8];
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Value::decode_from(&huge, &mut 0).is_err());
        // Tensor dims product overflowing usize.
        let mut ovf = vec![0u8, 16];
        for _ in 0..16 {
            ovf.extend_from_slice(&(u32::MAX).to_le_bytes());
        }
        assert!(Value::decode_from(&ovf, &mut 0).is_err());
    }

    #[test]
    fn pool_parking_is_bounded() {
        // A consumer that never releases its clones (pathological
        // retention) must not grow the pool without bound.
        let mut pool = TensorPool::new(Arc::new(AtomicU64::new(0)), 3);
        let mut retained = Vec::new();
        for _ in 0..10 {
            let b = pool.take_free(4);
            retained.push(b.clone()); // held forever
            pool.put_back(b);
        }
        assert_eq!(pool.parked(), 3, "parking capped at max_parked");
    }
}
