//! The orchestrator substrate: the SmartSim-Orchestrator analogue
//! (DESIGN.md S8).  An in-memory tensor datastore deployed by the
//! coordinator ("head node"), through which environment workers and the
//! trainer exchange states, actions and done-flags — the same dataflow and
//! the same central-bottleneck architecture as the paper's Redis/KeyDB
//! database, with client handles playing the role of SmartRedis.

pub mod protocol;
pub mod store;
pub mod value;

pub use protocol::Protocol;
pub use store::{ShardedStore, StatsSnapshot};
pub use value::Value;

use std::sync::Arc;
use std::time::Duration;

/// The orchestrator: a launched store plus client factory.
pub struct Orchestrator {
    store: Arc<ShardedStore>,
}

impl Orchestrator {
    /// "Launch" the datastore (paper: on the head node, before training).
    /// `shards = 1` gives the single-threaded-Redis behaviour; more shards
    /// give the KeyDB behaviour.
    pub fn launch(shards: usize) -> Orchestrator {
        Orchestrator {
            store: Arc::new(ShardedStore::new(shards)),
        }
    }

    /// A client handle (cheap to clone across worker threads).
    pub fn client(&self) -> Client {
        Client {
            store: self.store.clone(),
        }
    }

    /// Direct store access (benches, tests).
    pub fn store(&self) -> &Arc<ShardedStore> {
        &self.store
    }

    /// Drop all keys (between iterations).
    pub fn clear(&self) {
        self.store.clear();
    }

    /// Counter snapshot.
    pub fn stats(&self) -> StatsSnapshot {
        self.store.stats()
    }
}

/// Client handle — the SmartRedis-client analogue used by both the
/// environment side (Fortran client in the paper) and the trainer side
/// (Python client in the paper).
#[derive(Clone)]
pub struct Client {
    store: Arc<ShardedStore>,
}

impl Client {
    /// Write a tensor.
    pub fn put_tensor(&self, key: &str, shape: Vec<usize>, data: Vec<f32>) {
        self.store.put(key, Value::tensor(shape, data));
    }

    /// Write a flag.
    pub fn put_flag(&self, key: &str, v: bool) {
        self.store.put(key, Value::Flag(v));
    }

    /// Write a scalar.
    pub fn put_scalar(&self, key: &str, v: f64) {
        self.store.put(key, Value::Scalar(v));
    }

    /// Write opaque bytes (failure reports, metadata).
    pub fn put_bytes(&self, key: &str, v: Vec<u8>) {
        self.store.put(key, Value::Bytes(v));
    }

    /// Non-blocking read.
    pub fn get(&self, key: &str) -> Option<Value> {
        self.store.get(key)
    }

    /// Blocking poll until the key appears (SmartRedis `poll_tensor`).
    pub fn poll(&self, key: &str, timeout: Duration) -> Option<Value> {
        self.store.wait_for(key, timeout)
    }

    /// Blocking poll that consumes the value.
    pub fn poll_take(&self, key: &str, timeout: Duration) -> Option<Value> {
        self.store.wait_take(key, timeout)
    }

    /// Blocking multi-key subscription: first of `keys` to appear wins
    /// (ties broken by argument order).  The arrival-order primitive the
    /// event-driven rollout collector consumes states through.
    pub fn poll_any(&self, keys: &[&str], timeout: Duration) -> Option<(usize, Value)> {
        self.store.wait_any(keys, timeout)
    }

    /// Like [`Client::poll_any`], but consumes the returned value.
    pub fn poll_any_take(&self, keys: &[&str], timeout: Duration) -> Option<(usize, Value)> {
        self.store.wait_any_take(keys, timeout)
    }

    /// Delete a key.
    pub fn delete(&self, key: &str) -> bool {
        self.store.delete(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_state_action_exchange() {
        // One simulated env worker and one trainer exchanging one step.
        let orch = Orchestrator::launch(4);
        let proto = Protocol::new("t");
        let env_client = orch.client();
        let trainer_client = orch.client();
        let p = proto.clone();

        let worker = std::thread::spawn(move || {
            // env writes its state, then waits for the action
            env_client.put_tensor(&p.state_key(0, 0), vec![2], vec![1.0, 2.0]);
            let act = env_client
                .poll_take(&p.action_key(0, 0), Duration::from_secs(5))
                .expect("no action");
            let data = act.as_tensor().unwrap().1.to_vec();
            env_client.put_flag(&p.done_key(0), true);
            data
        });

        let state = trainer_client
            .poll(&proto.state_key(0, 0), Duration::from_secs(5))
            .expect("no state");
        assert_eq!(state.as_tensor().unwrap().1, &[1.0, 2.0]);
        trainer_client.put_tensor(&proto.action_key(0, 0), vec![1], vec![0.17]);
        let act = worker.join().unwrap();
        assert_eq!(act, vec![0.17]);
        assert_eq!(
            trainer_client
                .poll(&proto.done_key(0), Duration::from_secs(5))
                .unwrap()
                .as_flag(),
            Some(true)
        );
    }

    #[test]
    fn client_helpers() {
        let orch = Orchestrator::launch(1);
        let c = orch.client();
        c.put_scalar("s", 2.0);
        assert_eq!(c.get("s").unwrap().as_scalar(), Some(2.0));
        assert!(c.delete("s"));
        assert!(c.get("s").is_none());
        assert!(orch.stats().puts >= 1);
        orch.clear();
        assert!(orch.store().is_empty());
    }
}
