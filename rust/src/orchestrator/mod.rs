//! The orchestrator substrate: the SmartSim-Orchestrator analogue
//! (DESIGN.md S8).  An in-memory tensor datastore deployed by the
//! coordinator ("head node"), through which environment workers and the
//! trainer exchange states, actions and done-flags — the same dataflow and
//! the same central-bottleneck architecture as the paper's Redis/KeyDB
//! database, with client handles playing the role of SmartRedis.
//!
//! The data plane is zero-copy: tensor payloads are `Arc<[f32]>`, so
//! reads and subscription hits bump a refcount instead of deep-copying
//! the state tensor, and producers can republish reusable buffers
//! ([`Client::put_tensor_shared`] + [`value::TensorPool`]).  Every client
//! operation accepts either a `&str` or an interned [`store::Key`]
//! (precomputed hash — [`Protocol`] builds per-(env, step) handles for
//! the rollout hot path).

pub mod protocol;
pub mod store;
pub mod transport;
pub mod value;
pub mod waverig;

pub use protocol::{EnvKeys, PoolKeys, Protocol};
pub use store::{Key, KeyLike, ShardedStore, StatsSnapshot, Subscription, WakeMode};
pub use transport::{
    ExchangeServer, InprocTransport, RemoteTransport, Transport, TransportFault, TransportSub,
    TRANSPORTS,
};
pub use value::{TensorPool, Value};

use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// The orchestrator: a launched store plus client factory.
pub struct Orchestrator {
    store: Arc<ShardedStore>,
}

impl Orchestrator {
    /// "Launch" the datastore (paper: on the head node, before training).
    /// `shards = 1` gives the single-threaded-Redis behaviour; more shards
    /// give the KeyDB behaviour.  Uses the default per-key wakeup
    /// protocol; see [`Orchestrator::launch_mode`].
    pub fn launch(shards: usize) -> Orchestrator {
        Orchestrator::launch_mode(shards, WakeMode::PerKey)
    }

    /// Launch with an explicit multi-key wakeup protocol
    /// (`WakeMode::SeqLock` retains the PR-2 sequence-lock baseline,
    /// selectable via `hpc.db_seqlock_wake`).
    pub fn launch_mode(shards: usize, wake: WakeMode) -> Orchestrator {
        Orchestrator {
            store: Arc::new(ShardedStore::with_wake_mode(shards, wake)),
        }
    }

    /// A client handle (cheap to clone across worker threads).
    pub fn client(&self) -> Client {
        Client {
            backend: ClientBackend::Inproc(self.store.clone()),
        }
    }

    /// Expose this store to other processes: bind an
    /// [`ExchangeServer`] on `bind` (e.g. `"127.0.0.1:0"`).  Remote
    /// clients ([`Client::remote`]) then share the exact same key
    /// space and blocking-op guarantees as in-process clients.
    pub fn serve(&self, bind: &str) -> Result<ExchangeServer> {
        ExchangeServer::bind(self.store.clone(), bind)
    }

    /// Direct store access (benches, tests).
    pub fn store(&self) -> &Arc<ShardedStore> {
        &self.store
    }

    /// Drop all keys (between iterations).
    pub fn clear(&self) {
        self.store.clear();
    }

    /// Counter snapshot.
    pub fn stats(&self) -> StatsSnapshot {
        self.store.stats()
    }
}

/// The transport behind a [`Client`], resolved once at construction.
/// The in-process arm calls the store directly — no trait object, no
/// re-boxing of payloads, bit-identical to the pre-seam path.  The
/// remote arm speaks a wire transport ([`transport::RemoteTransport`]).
#[derive(Clone)]
enum ClientBackend {
    Inproc(Arc<ShardedStore>),
    Remote(Arc<dyn Transport>),
}

/// A remote transport failure is unrecoverable for the no-`Result`
/// `Client` API (the transport already retried once on a fresh
/// connection): report and die — the env-worker control loop, which
/// needs a *clean* exit on trainer death, talks to the [`Transport`]
/// directly instead of through `Client`.
fn transported<T>(kind: &str, r: Result<T>) -> T {
    r.unwrap_or_else(|e| panic!("orchestrator {kind} transport failed: {e:#}"))
}

/// Client handle — the SmartRedis-client analogue used by both the
/// environment side (Fortran client in the paper) and the trainer side
/// (Python client in the paper).  Every method takes any [`KeyLike`]:
/// plain `&str`, `&String`, or a precomputed [`Key`] handle.
///
/// A client is either in-process (from [`Orchestrator::client`]) or
/// remote (from [`Client::remote`], dialing an [`ExchangeServer`]);
/// the API and blocking semantics are identical either way.
#[derive(Clone)]
pub struct Client {
    backend: ClientBackend,
}

impl Client {
    /// A client over a remote transport (see
    /// [`transport::RemoteTransport::connect`]).  Transport failures
    /// panic with context; callers needing graceful degradation use
    /// the [`Transport`] trait directly.
    pub fn remote(transport: Arc<dyn Transport>) -> Client {
        Client {
            backend: ClientBackend::Remote(transport),
        }
    }

    /// The transport kind serving this client (`"inproc"`, `"shm"`,
    /// `"tcp"`).
    pub fn transport_kind(&self) -> &'static str {
        match &self.backend {
            ClientBackend::Inproc(_) => "inproc",
            ClientBackend::Remote(t) => t.kind(),
        }
    }

    /// Write a tensor from owned vectors (moved into shared buffers).
    pub fn put_tensor<K: KeyLike + ?Sized>(&self, key: &K, shape: Vec<usize>, data: Vec<f32>) {
        match &self.backend {
            ClientBackend::Inproc(store) => store.put(key, Value::tensor(shape, data)),
            ClientBackend::Remote(t) => {
                transported(t.kind(), t.put(key.name(), Value::tensor(shape, data)))
            }
        }
    }

    /// Write a tensor from already-shared buffers — the zero-copy publish
    /// path: the store holds a refcount on the caller's buffer, and no
    /// float is copied anywhere.  (Over a remote transport the wire copy
    /// is unavoidable; the buffer handle itself still isn't re-boxed.)
    pub fn put_tensor_shared<K: KeyLike + ?Sized>(
        &self,
        key: &K,
        shape: Arc<[usize]>,
        data: Arc<[f32]>,
    ) {
        match &self.backend {
            ClientBackend::Inproc(store) => store.put(key, Value::tensor_shared(shape, data)),
            ClientBackend::Remote(t) => {
                transported(t.kind(), t.put(key.name(), Value::tensor_shared(shape, data)))
            }
        }
    }

    /// Write a flag.
    pub fn put_flag<K: KeyLike + ?Sized>(&self, key: &K, v: bool) {
        match &self.backend {
            ClientBackend::Inproc(store) => store.put(key, Value::Flag(v)),
            ClientBackend::Remote(t) => transported(t.kind(), t.put(key.name(), Value::Flag(v))),
        }
    }

    /// Write a scalar.
    pub fn put_scalar<K: KeyLike + ?Sized>(&self, key: &K, v: f64) {
        match &self.backend {
            ClientBackend::Inproc(store) => store.put(key, Value::Scalar(v)),
            ClientBackend::Remote(t) => transported(t.kind(), t.put(key.name(), Value::Scalar(v))),
        }
    }

    /// Write opaque bytes (failure reports, metadata).
    pub fn put_bytes<K: KeyLike + ?Sized>(&self, key: &K, v: Vec<u8>) {
        match &self.backend {
            ClientBackend::Inproc(store) => store.put(key, Value::bytes(v)),
            ClientBackend::Remote(t) => transported(t.kind(), t.put(key.name(), Value::bytes(v))),
        }
    }

    /// Non-blocking read (payloads shared, not copied).
    pub fn get<K: KeyLike + ?Sized>(&self, key: &K) -> Option<Value> {
        match &self.backend {
            ClientBackend::Inproc(store) => store.get(key),
            ClientBackend::Remote(t) => transported(t.kind(), t.get(key.name())),
        }
    }

    /// Blocking poll until the key appears (SmartRedis `poll_tensor`).
    pub fn poll<K: KeyLike + ?Sized>(&self, key: &K, timeout: Duration) -> Option<Value> {
        match &self.backend {
            ClientBackend::Inproc(store) => store.wait_for(key, timeout),
            ClientBackend::Remote(t) => transported(t.kind(), t.wait(key.name(), timeout, false)),
        }
    }

    /// Blocking poll that consumes the value.
    pub fn poll_take<K: KeyLike + ?Sized>(&self, key: &K, timeout: Duration) -> Option<Value> {
        match &self.backend {
            ClientBackend::Inproc(store) => store.wait_take(key, timeout),
            ClientBackend::Remote(t) => transported(t.kind(), t.wait(key.name(), timeout, true)),
        }
    }

    /// Blocking multi-key subscription: first of `keys` to appear wins
    /// (ties among already-present keys broken by argument order).  The
    /// arrival-order primitive the event-driven rollout collector
    /// consumes states through; with the per-key wakeup protocol a put
    /// wakes only the subscribers of that key.
    pub fn poll_any<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
    ) -> Option<(usize, Value)> {
        match &self.backend {
            ClientBackend::Inproc(store) => store.wait_any(keys, timeout),
            ClientBackend::Remote(t) => {
                let names: Vec<&str> = keys.iter().map(|k| k.name()).collect();
                transported(t.kind(), t.wait_any(&names, timeout, false))
            }
        }
    }

    /// Like [`Client::poll_any`], but consumes the returned value.
    pub fn poll_any_take<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
    ) -> Option<(usize, Value)> {
        match &self.backend {
            ClientBackend::Inproc(store) => store.wait_any_take(keys, timeout),
            ClientBackend::Remote(t) => {
                let names: Vec<&str> = keys.iter().map(|k| k.name()).collect();
                transported(t.kind(), t.wait_any(&names, timeout, true))
            }
        }
    }

    /// A persistent multi-key subscription (see
    /// [`store::Subscription`]): register once, apply add/remove key
    /// deltas between waits.  The event-driven rollout collector holds
    /// one per sampling phase, making a collection wave O(envs) registry
    /// ops instead of the O(envs²) of per-event `poll_any` rebuilds.
    /// Over a remote transport the subscription pins one connection with
    /// a real server-side `Subscription` behind it.
    pub fn subscription(&self) -> ClientSub {
        match &self.backend {
            ClientBackend::Inproc(store) => ClientSub {
                inner: ClientSubInner::Inproc(Subscription::new(store.clone())),
            },
            ClientBackend::Remote(t) => ClientSub {
                inner: ClientSubInner::Remote(t.kind(), transported(t.kind(), t.subscribe())),
            },
        }
    }

    /// Delete a key.
    pub fn delete<K: KeyLike + ?Sized>(&self, key: &K) -> bool {
        match &self.backend {
            ClientBackend::Inproc(store) => store.delete(key),
            ClientBackend::Remote(t) => transported(t.kind(), t.delete(key.name())),
        }
    }

    /// Batched put: every item lands atomically-per-key in one logical
    /// op — one grouped-by-shard store pass in process, ONE wire frame
    /// per worker block on remote transports (the PR-9 coalescing
    /// unit).  Interned [`Key`] handles keep the inproc path free of
    /// per-key string allocation.
    pub fn put_many(&self, items: Vec<(Key, Value)>) {
        match &self.backend {
            ClientBackend::Inproc(store) => store.put_many(items),
            ClientBackend::Remote(t) => {
                let wire: Vec<(String, Value)> = items
                    .into_iter()
                    .map(|(k, v)| (k.name().to_string(), v))
                    .collect();
                transported(t.kind(), t.put_many(wire));
            }
        }
    }

    /// Blocking batched take: wait until **any** of `keys` holds a
    /// value, then atomically consume **all** present ones, returned as
    /// `(index, value)` in ascending index order (empty = timeout).
    /// One wire frame per call on remote transports; exactly-once per
    /// key on every backend.
    pub fn take_many<K: KeyLike + ?Sized>(
        &self,
        keys: &[&K],
        timeout: Duration,
    ) -> Vec<(usize, Value)> {
        match &self.backend {
            ClientBackend::Inproc(store) => store.take_many_wait(keys, timeout),
            ClientBackend::Remote(t) => {
                let names: Vec<&str> = keys.iter().map(|k| k.name()).collect();
                transported(t.kind(), t.take_many(&names, timeout))
            }
        }
    }
}

/// The transport-spanning face of [`store::Subscription`], returned by
/// [`Client::subscription`] — same method surface and delivery
/// guarantees on every transport.
pub struct ClientSub {
    inner: ClientSubInner,
}

enum ClientSubInner {
    Inproc(Subscription),
    Remote(&'static str, Box<dyn TransportSub>),
}

impl ClientSub {
    /// Register `key` under `tag` (replacing the tag's previous key).
    pub fn add<K: KeyLike + ?Sized>(&mut self, tag: usize, key: &K) {
        match &mut self.inner {
            ClientSubInner::Inproc(s) => s.add(tag, key),
            ClientSubInner::Remote(kind, s) => transported(kind, s.add(tag, key.name())),
        }
    }

    /// Drop the registration under `tag`.
    pub fn remove(&mut self, tag: usize) {
        match &mut self.inner {
            ClientSubInner::Inproc(s) => s.remove(tag),
            ClientSubInner::Remote(kind, s) => transported(kind, s.remove(tag)),
        }
    }

    /// Take the first value to appear under any registered tag.
    pub fn wait_take(&mut self, timeout: Duration) -> Option<(usize, Value)> {
        match &mut self.inner {
            ClientSubInner::Inproc(s) => s.wait_take(timeout),
            ClientSubInner::Remote(kind, s) => transported(kind, s.wait_take(timeout)),
        }
    }

    /// Batched [`ClientSub::wait_take`]: block for the first delivery,
    /// then drain up to `max - 1` more without blocking (one wire frame
    /// per call on remote transports).  Empty vec = timeout.
    pub fn wait_take_many(&mut self, timeout: Duration, max: usize) -> Vec<(usize, Value)> {
        match &mut self.inner {
            ClientSubInner::Inproc(s) => s.wait_take_many(timeout, max),
            ClientSubInner::Remote(kind, s) => transported(kind, s.wait_take_many(timeout, max)),
        }
    }

    /// Registered tag count.
    pub fn len(&self) -> usize {
        match &self.inner {
            ClientSubInner::Inproc(s) => s.len(),
            ClientSubInner::Remote(_, s) => s.len(),
        }
    }

    /// True when no tags are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_state_action_exchange() {
        // One simulated env worker and one trainer exchanging one step.
        let orch = Orchestrator::launch(4);
        let proto = Protocol::new("t");
        let env_client = orch.client();
        let trainer_client = orch.client();
        let p = proto.clone();

        let worker = std::thread::spawn(move || {
            // env writes its state, then waits for the action
            env_client.put_tensor(&p.state_key(0, 0), vec![2], vec![1.0, 2.0]);
            let act = env_client
                .poll_take(&p.action_key(0, 0), Duration::from_secs(5))
                .expect("no action");
            let data = act.as_tensor().unwrap().1.to_vec();
            env_client.put_flag(&p.done_key(0), true);
            data
        });

        let state = trainer_client
            .poll(&proto.state_key(0, 0), Duration::from_secs(5))
            .expect("no state");
        assert_eq!(state.as_tensor().unwrap().1, &[1.0, 2.0]);
        trainer_client.put_tensor(&proto.action_key(0, 0), vec![1], vec![0.17]);
        let act = worker.join().unwrap();
        assert_eq!(act, vec![0.17]);
        assert_eq!(
            trainer_client
                .poll(&proto.done_key(0), Duration::from_secs(5))
                .unwrap()
                .as_flag(),
            Some(true)
        );
    }

    #[test]
    fn client_helpers() {
        let orch = Orchestrator::launch(1);
        let c = orch.client();
        c.put_scalar("s", 2.0);
        assert_eq!(c.get("s").unwrap().as_scalar(), Some(2.0));
        assert!(c.delete("s"));
        assert!(c.get("s").is_none());
        assert!(orch.stats().puts >= 1);
        orch.clear();
        assert!(orch.store().is_empty());
    }

    #[test]
    fn shared_publish_is_zero_copy_end_to_end() {
        let orch = Orchestrator::launch(4);
        let c = orch.client();
        let data: Arc<[f32]> = Arc::from(vec![0.25f32; 1024]);
        let shape: Arc<[usize]> = Arc::from(vec![1024usize]);
        c.put_tensor_shared("state", shape, data.clone());
        let got = c.get("state").unwrap().tensor_data().unwrap();
        assert!(Arc::ptr_eq(&got, &data), "consumer shares the producer buffer");
        let (_, v) = c
            .poll_any_take(&["state"], Duration::from_secs(1))
            .unwrap();
        assert!(Arc::ptr_eq(&v.tensor_data().unwrap(), &data));
    }

    #[test]
    fn remote_client_has_identical_semantics_to_inproc() {
        let orch = Orchestrator::launch(4);
        let server = orch.serve("127.0.0.1:0").unwrap();
        let remote = Client::remote(
            RemoteTransport::connect("tcp", &server.addr().to_string(), 1).unwrap(),
        );
        assert_eq!(remote.transport_kind(), "tcp");
        assert_eq!(orch.client().transport_kind(), "inproc");

        let proto = Protocol::new("r");
        let keys = proto.env_keys(0, 1);
        // Interned keys work over the wire (resolved by name).
        remote.put_tensor(&keys.state[0], vec![2], vec![1.0, 2.0]);
        let local = orch.client();
        let v = local.poll_take(&proto.state_key(0, 0), Duration::from_secs(5)).unwrap();
        assert_eq!(v.as_tensor().unwrap().1, &[1.0, 2.0]);

        local.put_scalar(&keys.rew[0], 0.75);
        let mut sub = remote.subscription();
        sub.add(9, &keys.rew[0]);
        let (tag, v) = sub.wait_take(Duration::from_secs(5)).unwrap();
        assert_eq!((tag, v.as_scalar()), (9, Some(0.75)));
        assert_eq!(sub.len(), 1);
        sub.remove(9);
        assert!(sub.is_empty());

        remote.put_flag(&keys.done, true);
        assert_eq!(remote.get(&keys.done).unwrap().as_flag(), Some(true));
        assert!(remote.delete(&keys.done));
        assert!(remote
            .poll_any(&[&keys.fail, &keys.abort], Duration::from_millis(50))
            .is_none());
    }

    #[test]
    fn interned_protocol_keys_work_through_the_client() {
        let orch = Orchestrator::launch_mode(4, WakeMode::PerKey);
        let c = orch.client();
        let proto = Protocol::new("it0");
        let keys = proto.env_keys(0, 2);
        c.put_scalar(&keys.rew[1], 0.5);
        c.put_flag(&keys.done, true);
        let (hit, v) = c
            .poll_any(&[&keys.rew[0], &keys.rew[1]], Duration::from_secs(1))
            .unwrap();
        assert_eq!((hit, v.as_scalar()), (1, Some(0.5)));
        // Interned and string forms address the same key.
        assert_eq!(c.get(&proto.done_key(0)).unwrap().as_flag(), Some(true));
        assert!(c.delete(&keys.done));
    }
}
