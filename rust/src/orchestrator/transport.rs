//! The transport seam: the orchestrator exchange behind a network-capable
//! boundary (ROADMAP open item 1, the paper's §5 "hundreds of parallel
//! environments" axis).
//!
//! The authoritative [`ShardedStore`] always lives in the trainer
//! process.  Three registered transports reach it:
//!
//! * `inproc` — today's path: the [`crate::orchestrator::Client`] enum
//!   resolves to a direct `Arc<ShardedStore>` call at construction, so
//!   the in-process data plane is bit-identical and allocation-free —
//!   no payload re-boxing, no dynamic dispatch on the hot path.
//! * `tcp` — length-prefixed binary frames over a [`TcpListener`]
//!   ([`ExchangeServer`]).  Every connection gets a dedicated server
//!   handler thread that executes ops against the real store — blocking
//!   ops (`wait_take`, subscription waits) run server-side in bounded
//!   slices, so the exactly-once / no-lost-wakeup guarantees of the
//!   store transfer by construction instead of being re-implemented in
//!   a wire protocol.
//! * `shm` — the same frame codec over a pair of SPSC byte rings in a
//!   memory-mapped segment, bootstrapped over one TCP handshake
//!   ([`Request::ShmOpen`]) and then entirely kernel-bypass for data:
//!   a tensor crosses the process boundary as one copy into the ring
//!   and one copy out.
//!
//! Frame layout: `u32 len (LE) | payload`, with the payload's first
//! byte an opcode ([`Request`]/[`Response`]).  All decoding is
//! panic-free: truncated frames, oversized lengths and trailing bytes
//! are recoverable `Err`s (fuzzed in the integration suite).

use super::store::{ShardedStore, Subscription};
use super::value::{wire, Value, MAX_PAYLOAD};
use crate::util::retry::RetryPolicy;
use anyhow::{bail, ensure, Context as _, Result};
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The registered transport kinds (`[orchestrator] transport` config).
pub const TRANSPORTS: &[&str] = &["inproc", "shm", "tcp"];

/// Hard cap on one frame's payload: the largest tensor plus codec
/// overhead.  A length prefix beyond this is rejected before any
/// allocation happens.
pub const MAX_FRAME: usize = MAX_PAYLOAD + (1 << 16);

/// Server-side blocking ops run in slices of this length so shutdown
/// and disconnects are observed promptly; each inner store wait is
/// atomic, so slicing never double-delivers.
const SLICE: Duration = Duration::from_millis(250);

/// Extra client-side patience on top of a blocking op's own timeout
/// before the connection is declared dead.
const RPC_GRACE: Duration = Duration::from_secs(10);

/// Deadline for plain request/response ops (server answers immediately).
const RPC_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-direction shared-memory ring capacity.  Frames larger than the
/// ring are streamed through it in chunks.
const SHM_RING_BYTES: usize = 1 << 20;

/// How long a shm ring write may stall (peer not draining) before the
/// connection is declared dead.
const SHM_STALL_LIMIT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------------
// Wire codec
// ---------------------------------------------------------------------------

/// A client request frame.  `timeout_ms` rides the wire explicitly so
/// the *server* runs the blocking wait — the client never polls.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Put { key: String, value: Value },
    Get { key: String },
    Take { key: String },
    Exists { key: String },
    Delete { key: String },
    Clear,
    /// `wait_for` (`take = false`) / `wait_take` (`take = true`).
    Wait { key: String, timeout_ms: u64, take: bool },
    /// `wait_any` / `wait_any_take`.
    WaitAny { keys: Vec<String>, timeout_ms: u64, take: bool },
    /// Delta ops on this connection's server-side [`Subscription`].
    SubAdd { tag: u64, key: String },
    SubRemove { tag: u64 },
    SubWait { timeout_ms: u64 },
    /// Clean shutdown of this connection.
    Bye,
    /// Upgrade this TCP connection to shared-memory rings: the client
    /// has created and sized the segment file at `path`; the server
    /// maps it (and the client then unlinks it).
    ShmOpen { path: String, ring_bytes: u64 },
    /// Batched put: all items land in one grouped-by-shard store pass
    /// (one frame per worker block per step, the PR-9 coalescing unit).
    PutMany { items: Vec<(String, Value)> },
    /// Blocking batched take: wait until **any** of `keys` is present,
    /// then atomically consume **all** present ones.  The response
    /// carries `(index into keys, value)` pairs; an empty list means
    /// the timeout elapsed with nothing present.
    TakeMany { keys: Vec<String>, timeout_ms: u64 },
    /// Batched wait on this connection's server-side [`Subscription`]:
    /// block for the first delivery, then drain up to `max` queued
    /// deliveries without blocking again.
    SubWaitMany { timeout_ms: u64, max: u32 },
}

/// A server response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Unit,
    Bool(bool),
    /// `Option<Value>` results (get/take/wait).
    Maybe(Option<Value>),
    /// `Option<(index-or-tag, Value)>` results (wait_any/sub_wait).
    Hit(Option<(u64, Value)>),
    /// `(index-or-tag, Value)` lists (take_many/sub_wait_many); empty
    /// means the timeout elapsed with nothing to deliver.
    Many(Vec<(u64, Value)>),
    Error(String),
}

impl Request {
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        use wire::*;
        match self {
            Request::Put { key, value } => {
                out.push(1);
                w_str(out, key);
                value.encode_into(out);
            }
            Request::Get { key } => {
                out.push(2);
                w_str(out, key);
            }
            Request::Take { key } => {
                out.push(3);
                w_str(out, key);
            }
            Request::Exists { key } => {
                out.push(4);
                w_str(out, key);
            }
            Request::Delete { key } => {
                out.push(5);
                w_str(out, key);
            }
            Request::Clear => out.push(6),
            Request::Wait { key, timeout_ms, take } => {
                out.push(7);
                w_str(out, key);
                w_u64(out, *timeout_ms);
                out.push(*take as u8);
            }
            Request::WaitAny { keys, timeout_ms, take } => {
                out.push(8);
                w_u32(out, keys.len() as u32);
                for k in keys {
                    w_str(out, k);
                }
                w_u64(out, *timeout_ms);
                out.push(*take as u8);
            }
            Request::SubAdd { tag, key } => {
                out.push(9);
                w_u64(out, *tag);
                w_str(out, key);
            }
            Request::SubRemove { tag } => {
                out.push(10);
                w_u64(out, *tag);
            }
            Request::SubWait { timeout_ms } => {
                out.push(11);
                w_u64(out, *timeout_ms);
            }
            Request::Bye => out.push(12),
            Request::ShmOpen { path, ring_bytes } => {
                out.push(13);
                w_str(out, path);
                w_u64(out, *ring_bytes);
            }
            Request::PutMany { items } => {
                out.push(14);
                w_u32(out, items.len() as u32);
                for (k, v) in items {
                    w_str(out, k);
                    v.encode_into(out);
                }
            }
            Request::TakeMany { keys, timeout_ms } => {
                out.push(15);
                w_u32(out, keys.len() as u32);
                for k in keys {
                    w_str(out, k);
                }
                w_u64(out, *timeout_ms);
            }
            Request::SubWaitMany { timeout_ms, max } => {
                out.push(16);
                w_u64(out, *timeout_ms);
                w_u32(out, *max);
            }
        }
    }

    /// Decode one request frame payload.  The whole buffer must be
    /// consumed — interleaved/trailing bytes are an error, never a
    /// panic.
    pub fn decode(buf: &[u8]) -> Result<Request> {
        use wire::*;
        let mut pos = 0;
        let req = match r_u8(buf, &mut pos)? {
            1 => Request::Put {
                key: r_str(buf, &mut pos)?,
                value: Value::decode_from(buf, &mut pos)?,
            },
            2 => Request::Get { key: r_str(buf, &mut pos)? },
            3 => Request::Take { key: r_str(buf, &mut pos)? },
            4 => Request::Exists { key: r_str(buf, &mut pos)? },
            5 => Request::Delete { key: r_str(buf, &mut pos)? },
            6 => Request::Clear,
            7 => Request::Wait {
                key: r_str(buf, &mut pos)?,
                timeout_ms: r_u64(buf, &mut pos)?,
                take: r_bool(buf, &mut pos)?,
            },
            8 => {
                let n = r_u32(buf, &mut pos)? as usize;
                ensure!(n <= 1 << 16, "wait_any claims {n} keys");
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    keys.push(r_str(buf, &mut pos)?);
                }
                Request::WaitAny {
                    keys,
                    timeout_ms: r_u64(buf, &mut pos)?,
                    take: r_bool(buf, &mut pos)?,
                }
            }
            9 => Request::SubAdd {
                tag: r_u64(buf, &mut pos)?,
                key: r_str(buf, &mut pos)?,
            },
            10 => Request::SubRemove { tag: r_u64(buf, &mut pos)? },
            11 => Request::SubWait { timeout_ms: r_u64(buf, &mut pos)? },
            12 => Request::Bye,
            13 => Request::ShmOpen {
                path: r_str(buf, &mut pos)?,
                ring_bytes: r_u64(buf, &mut pos)?,
            },
            14 => {
                let n = r_u32(buf, &mut pos)? as usize;
                ensure!(n <= 1 << 16, "put_many claims {n} items");
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = r_str(buf, &mut pos)?;
                    let v = Value::decode_from(buf, &mut pos)?;
                    items.push((k, v));
                }
                Request::PutMany { items }
            }
            15 => {
                let n = r_u32(buf, &mut pos)? as usize;
                ensure!(n <= 1 << 16, "take_many claims {n} keys");
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    keys.push(r_str(buf, &mut pos)?);
                }
                Request::TakeMany {
                    keys,
                    timeout_ms: r_u64(buf, &mut pos)?,
                }
            }
            16 => Request::SubWaitMany {
                timeout_ms: r_u64(buf, &mut pos)?,
                max: r_u32(buf, &mut pos)?,
            },
            other => bail!("unknown request opcode {other}"),
        };
        ensure!(pos == buf.len(), "trailing bytes in request frame");
        Ok(req)
    }
}

impl Response {
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        use wire::*;
        match self {
            Response::Unit => out.push(128),
            Response::Bool(b) => {
                out.push(129);
                out.push(*b as u8);
            }
            Response::Maybe(v) => {
                out.push(130);
                out.push(v.is_some() as u8);
                if let Some(v) = v {
                    v.encode_into(out);
                }
            }
            Response::Hit(h) => {
                out.push(131);
                out.push(h.is_some() as u8);
                if let Some((idx, v)) = h {
                    w_u64(out, *idx);
                    v.encode_into(out);
                }
            }
            Response::Many(hits) => {
                out.push(132);
                w_u32(out, hits.len() as u32);
                for (idx, v) in hits {
                    w_u64(out, *idx);
                    v.encode_into(out);
                }
            }
            Response::Error(msg) => {
                out.push(255);
                // Bound the message so it always fits the u16 length.
                let mut end = msg.len().min(512);
                while !msg.is_char_boundary(end) {
                    end -= 1;
                }
                w_str(out, &msg[..end]);
            }
        }
    }

    /// Decode one response frame payload (whole-buffer, panic-free —
    /// same contract as [`Request::decode`]).
    pub fn decode(buf: &[u8]) -> Result<Response> {
        use wire::*;
        let mut pos = 0;
        let resp = match r_u8(buf, &mut pos)? {
            128 => Response::Unit,
            129 => Response::Bool(r_bool(buf, &mut pos)?),
            130 => {
                if r_bool(buf, &mut pos)? {
                    Response::Maybe(Some(Value::decode_from(buf, &mut pos)?))
                } else {
                    Response::Maybe(None)
                }
            }
            131 => {
                if r_bool(buf, &mut pos)? {
                    let idx = r_u64(buf, &mut pos)?;
                    Response::Hit(Some((idx, Value::decode_from(buf, &mut pos)?)))
                } else {
                    Response::Hit(None)
                }
            }
            132 => {
                let n = r_u32(buf, &mut pos)? as usize;
                ensure!(n <= 1 << 16, "many response claims {n} hits");
                let mut hits = Vec::with_capacity(n);
                for _ in 0..n {
                    let idx = r_u64(buf, &mut pos)?;
                    hits.push((idx, Value::decode_from(buf, &mut pos)?));
                }
                Response::Many(hits)
            }
            255 => Response::Error(r_str(buf, &mut pos)?),
            other => bail!("unknown response opcode {other}"),
        };
        ensure!(pos == buf.len(), "trailing bytes in response frame");
        Ok(resp)
    }
}

fn r_bool(buf: &[u8], pos: &mut usize) -> Result<bool> {
    match wire::r_u8(buf, pos)? {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("bool byte must be 0|1, got {other}"),
    }
}

/// Validate a frame length prefix (never allocates for a bad one).
pub fn frame_len(hdr: [u8; 4]) -> Result<usize> {
    let n = u32::from_le_bytes(hdr) as usize;
    ensure!(n >= 1, "empty frame");
    ensure!(n <= MAX_FRAME, "frame length {n} exceeds MAX_FRAME {MAX_FRAME}");
    Ok(n)
}

/// Pull one complete frame's payload out of an accumulation buffer.
/// `Ok(false)` = not enough bytes yet (partial input retained).
fn try_extract(accum: &mut Vec<u8>, out: &mut Vec<u8>) -> Result<bool> {
    if accum.len() < 4 {
        return Ok(false);
    }
    let n = frame_len(accum[..4].try_into().unwrap())?;
    if accum.len() < 4 + n {
        return Ok(false);
    }
    out.clear();
    out.extend_from_slice(&accum[4..4 + n]);
    accum.drain(..4 + n);
    Ok(true)
}

// ---------------------------------------------------------------------------
// Connections (framed byte pipes)
// ---------------------------------------------------------------------------

/// A framed, bidirectional byte pipe.  `recv` is resumable: timing out
/// mid-frame keeps the partial bytes buffered, so frame sync is never
/// lost.
trait Conn: Send {
    /// Write one frame (length prefix + payload).
    fn send(&mut self, payload: &[u8]) -> Result<()>;
    /// Burst-write several frames back-to-back — one vectored-style
    /// buffer assembly and one syscall on tcp, one ring pass on shm.
    /// The default loops over `send`.
    fn send_many(&mut self, payloads: &[&[u8]]) -> Result<()> {
        for p in payloads {
            self.send(p)?;
        }
        Ok(())
    }
    /// Receive one frame into `out`.  `Ok(true)` = frame delivered,
    /// `Ok(false)` = timed out, `Err` = disconnected or protocol error.
    fn recv(&mut self, out: &mut Vec<u8>, timeout: Duration) -> Result<bool>;
}

struct TcpConn {
    stream: TcpStream,
    accum: Vec<u8>,
    scratch: Box<[u8; 64 * 1024]>,
    /// Reusable send-side assembly buffer: prefix + payload (or a whole
    /// frame burst) leave in ONE `write_all` instead of one syscall per
    /// piece.
    wbuf: Vec<u8>,
}

impl TcpConn {
    fn new(stream: TcpStream) -> Result<TcpConn> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(TcpConn {
            stream,
            accum: Vec::new(),
            scratch: Box::new([0u8; 64 * 1024]),
            wbuf: Vec::new(),
        })
    }

    /// Surrender the raw stream (shm upgrade).  Refuses if bytes are
    /// already buffered — the peer must not pipeline past the upgrade.
    fn into_stream(self) -> Result<TcpStream> {
        ensure!(self.accum.is_empty(), "bytes pipelined past shm upgrade");
        Ok(self.stream)
    }
}

impl Conn for TcpConn {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        ensure!(payload.len() <= MAX_FRAME, "frame too large: {}", payload.len());
        self.wbuf.clear();
        self.wbuf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.wbuf.extend_from_slice(payload);
        self.stream.write_all(&self.wbuf).context("tcp write")?;
        Ok(())
    }

    fn send_many(&mut self, payloads: &[&[u8]]) -> Result<()> {
        self.wbuf.clear();
        for p in payloads {
            ensure!(p.len() <= MAX_FRAME, "frame too large: {}", p.len());
            self.wbuf.extend_from_slice(&(p.len() as u32).to_le_bytes());
            self.wbuf.extend_from_slice(p);
        }
        self.stream.write_all(&self.wbuf).context("tcp write")?;
        Ok(())
    }

    fn recv(&mut self, out: &mut Vec<u8>, timeout: Duration) -> Result<bool> {
        let deadline = Instant::now() + timeout;
        loop {
            if try_extract(&mut self.accum, out)? {
                return Ok(true);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(false);
            }
            let wait = (deadline - now).max(Duration::from_millis(1));
            self.stream.set_read_timeout(Some(wait)).context("set_read_timeout")?;
            match self.stream.read(&mut self.scratch[..]) {
                Ok(0) => bail!("connection closed by peer"),
                Ok(n) => self.accum.extend_from_slice(&self.scratch[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow::anyhow!("tcp read: {e}")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared-memory segment + rings (unix only)
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod shm {
    use super::*;
    use std::fs::OpenOptions;
    use std::os::unix::io::AsRawFd;

    const MAGIC: u64 = 0x52454C5853484D31; // "RELXSHM1"
    /// Header layout (offsets in bytes; hot words a cache line apart):
    ///   0 magic | 8 ring_bytes | 16 client_closed | 24 server_closed
    ///   64 c2s head | 128 c2s tail | 192 s2c head | 256 s2c tail
    pub const HDR: usize = 512;
    const OFF_MAGIC: usize = 0;
    const OFF_RING_BYTES: usize = 8;
    const OFF_CLIENT_CLOSED: usize = 16;
    const OFF_SERVER_CLOSED: usize = 24;

    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }
    const PROT_READ: i32 = 0x1;
    const PROT_WRITE: i32 = 0x2;
    const MAP_SHARED: i32 = 0x01;

    /// A mapped segment.  Both processes map the same file; the client
    /// unlinks it once the server confirms its mapping, so the memory
    /// lives exactly as long as the two mappings.
    pub struct Seg {
        base: *mut u8,
        len: usize,
    }
    // The raw pointer targets file-backed shared memory; all cross-
    // process coordination goes through the atomics below.
    unsafe impl Send for Seg {}

    impl Seg {
        /// Client side: create + size + map + initialize the segment.
        pub fn create(path: &std::path::Path, ring_bytes: usize) -> Result<Seg> {
            let len = HDR + 2 * ring_bytes;
            let file = OpenOptions::new()
                .read(true)
                .write(true)
                .create_new(true)
                .open(path)
                .with_context(|| format!("create shm segment {}", path.display()))?;
            file.set_len(len as u64).context("size shm segment")?;
            let seg = Seg::map(&file, len)?;
            seg.atomic(OFF_RING_BYTES).store(ring_bytes as u64, Ordering::Relaxed);
            seg.atomic(OFF_CLIENT_CLOSED).store(0, Ordering::Relaxed);
            seg.atomic(OFF_SERVER_CLOSED).store(0, Ordering::Relaxed);
            for r in 0..2 {
                seg.atomic(64 + r * 128).store(0, Ordering::Relaxed);
                seg.atomic(64 + r * 128 + 64).store(0, Ordering::Relaxed);
            }
            seg.atomic(OFF_MAGIC).store(MAGIC, Ordering::Release);
            Ok(seg)
        }

        /// Server side: map an existing segment, validating magic and
        /// the announced ring size against the file's actual length.
        pub fn open(path: &std::path::Path, ring_bytes: usize) -> Result<Seg> {
            let file = OpenOptions::new()
                .read(true)
                .write(true)
                .open(path)
                .with_context(|| format!("open shm segment {}", path.display()))?;
            let len = HDR + 2 * ring_bytes;
            ensure!(
                file.metadata().context("stat shm segment")?.len() == len as u64,
                "shm segment size disagrees with announced ring_bytes {ring_bytes}"
            );
            let seg = Seg::map(&file, len)?;
            ensure!(
                seg.atomic(OFF_MAGIC).load(Ordering::Acquire) == MAGIC,
                "shm segment has wrong magic"
            );
            ensure!(
                seg.atomic(OFF_RING_BYTES).load(Ordering::Relaxed) == ring_bytes as u64,
                "shm segment header ring_bytes mismatch"
            );
            Ok(seg)
        }

        fn map(file: &std::fs::File, len: usize) -> Result<Seg> {
            let base = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            ensure!(
                !base.is_null() && base as isize != -1,
                "mmap of {len}-byte shm segment failed"
            );
            Ok(Seg { base: base as *mut u8, len })
        }

        pub fn atomic(&self, off: usize) -> &AtomicU64 {
            debug_assert!(off % 8 == 0 && off + 8 <= self.len);
            unsafe { &*(self.base.add(off) as *const AtomicU64) }
        }

        fn data_ptr(&self, off: usize) -> *mut u8 {
            debug_assert!(off < self.len);
            unsafe { self.base.add(off) }
        }

        pub fn set_closed(&self, server: bool) {
            let off = if server { OFF_SERVER_CLOSED } else { OFF_CLIENT_CLOSED };
            self.atomic(off).store(1, Ordering::Release);
        }

        pub fn peer_closed(&self, i_am_server: bool) -> bool {
            let off = if i_am_server { OFF_CLIENT_CLOSED } else { OFF_SERVER_CLOSED };
            self.atomic(off).load(Ordering::Acquire) == 1
        }
    }

    impl Drop for Seg {
        fn drop(&mut self) {
            unsafe {
                munmap(self.base as *mut core::ffi::c_void, self.len);
            }
        }
    }

    /// One SPSC byte ring inside the segment (monotonic head/tail,
    /// indices reduced mod `cap` at access time).
    pub struct Ring {
        head_off: usize,
        tail_off: usize,
        data_off: usize,
        cap: usize,
    }

    impl Ring {
        /// Ring `which` (0 = client->server, 1 = server->client).
        pub fn new(which: usize, cap: usize) -> Ring {
            Ring {
                head_off: 64 + which * 128,
                tail_off: 64 + which * 128 + 64,
                data_off: HDR + which * cap,
                cap,
            }
        }

        /// Producer: write as much of `buf` as fits; returns bytes
        /// written (possibly 0).
        pub fn push(&self, seg: &Seg, buf: &[u8]) -> usize {
            let head = seg.atomic(self.head_off).load(Ordering::Relaxed);
            let tail = seg.atomic(self.tail_off).load(Ordering::Acquire);
            let used = head.wrapping_sub(tail) as usize;
            let n = buf.len().min(self.cap - used);
            if n == 0 {
                return 0;
            }
            let at = (head as usize) % self.cap;
            let first = n.min(self.cap - at);
            unsafe {
                std::ptr::copy_nonoverlapping(buf.as_ptr(), seg.data_ptr(self.data_off + at), first);
                if n > first {
                    std::ptr::copy_nonoverlapping(
                        buf.as_ptr().add(first),
                        seg.data_ptr(self.data_off),
                        n - first,
                    );
                }
            }
            seg.atomic(self.head_off).store(head.wrapping_add(n as u64), Ordering::Release);
            n
        }

        /// Consumer: drain up to `max` available bytes into `out`;
        /// returns bytes read (possibly 0).
        pub fn pop(&self, seg: &Seg, out: &mut Vec<u8>, max: usize) -> usize {
            let head = seg.atomic(self.head_off).load(Ordering::Acquire);
            let tail = seg.atomic(self.tail_off).load(Ordering::Relaxed);
            let avail = head.wrapping_sub(tail) as usize;
            let n = avail.min(max);
            if n == 0 {
                return 0;
            }
            let at = (tail as usize) % self.cap;
            let first = n.min(self.cap - at);
            let old = out.len();
            out.resize(old + n, 0);
            unsafe {
                std::ptr::copy_nonoverlapping(seg.data_ptr(self.data_off + at), out.as_mut_ptr().add(old), first);
                if n > first {
                    std::ptr::copy_nonoverlapping(
                        seg.data_ptr(self.data_off),
                        out.as_mut_ptr().add(old + first),
                        n - first,
                    );
                }
            }
            seg.atomic(self.tail_off).store(tail.wrapping_add(n as u64), Ordering::Release);
            n
        }
    }
}

/// Exponential spin -> yield -> sleep backoff for the shm rings.
#[cfg(unix)]
struct Backoff {
    step: u32,
}

#[cfg(unix)]
impl Backoff {
    fn new() -> Backoff {
        Backoff { step: 0 }
    }
    fn reset(&mut self) {
        self.step = 0;
    }
    fn snooze(&mut self) {
        if self.step < 6 {
            for _ in 0..(1 << self.step) {
                std::hint::spin_loop();
            }
        } else if self.step < 12 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
        self.step = self.step.saturating_add(1);
    }
    /// In the sleep regime, probe peer liveness roughly every ~20ms.
    fn should_probe(&self) -> bool {
        self.step >= 12 && self.step % 200 == 0
    }
}

#[cfg(unix)]
struct ShmConn {
    seg: shm::Seg,
    tx: shm::Ring,
    rx: shm::Ring,
    is_server: bool,
    /// The bootstrap TCP stream, kept open (nonblocking) purely as a
    /// liveness probe: a hard-killed peer can't set its closed flag,
    /// but the kernel closes its socket.
    bootstrap: TcpStream,
    accum: Vec<u8>,
    tx_buf: Vec<u8>,
}

#[cfg(unix)]
impl ShmConn {
    fn new(seg: shm::Seg, ring_bytes: usize, is_server: bool, bootstrap: TcpStream) -> Result<ShmConn> {
        bootstrap.set_nonblocking(true).context("bootstrap nonblocking")?;
        let (tx, rx) = if is_server {
            (shm::Ring::new(1, ring_bytes), shm::Ring::new(0, ring_bytes))
        } else {
            (shm::Ring::new(0, ring_bytes), shm::Ring::new(1, ring_bytes))
        };
        Ok(ShmConn {
            seg,
            tx,
            rx,
            is_server,
            bootstrap,
            accum: Vec::new(),
            tx_buf: Vec::new(),
        })
    }

    /// Err if the bootstrap socket reports the peer is gone.
    fn probe_liveness(&self) -> Result<()> {
        let mut b = [0u8; 1];
        match self.bootstrap.peek(&mut b) {
            Ok(0) => bail!("shm peer process is gone (bootstrap socket closed)"),
            Ok(_) => Ok(()), // unexpected data; harmless
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(()),
            Err(e) => bail!("shm bootstrap socket error: {e}"),
        }
    }

    /// Stream the assembled `tx_buf` into the ring (chunked to whatever
    /// space the consumer frees), with stall detection + liveness
    /// probing.
    fn drain_tx(&mut self) -> Result<()> {
        let mut buf = &self.tx_buf[..];
        let mut bo = Backoff::new();
        let deadline = Instant::now() + SHM_STALL_LIMIT;
        while !buf.is_empty() {
            let wrote = self.tx.push(&self.seg, buf);
            if wrote > 0 {
                buf = &buf[wrote..];
                bo.reset();
                continue;
            }
            if self.seg.peer_closed(self.is_server) {
                bail!("shm peer closed");
            }
            if Instant::now() >= deadline {
                bail!("shm ring stalled for {SHM_STALL_LIMIT:?} (peer not draining)");
            }
            bo.snooze();
            if bo.should_probe() {
                self.probe_liveness()?;
            }
        }
        Ok(())
    }
}

#[cfg(unix)]
impl Conn for ShmConn {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        ensure!(payload.len() <= MAX_FRAME, "frame too large: {}", payload.len());
        self.tx_buf.clear();
        self.tx_buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.tx_buf.extend_from_slice(payload);
        self.drain_tx()
    }

    fn send_many(&mut self, payloads: &[&[u8]]) -> Result<()> {
        // Multi-frame burst: all frames enter the ring back-to-back in
        // one streaming pass (the consumer sees them contiguously, no
        // per-frame wakeup gaps).
        self.tx_buf.clear();
        for p in payloads {
            ensure!(p.len() <= MAX_FRAME, "frame too large: {}", p.len());
            self.tx_buf.extend_from_slice(&(p.len() as u32).to_le_bytes());
            self.tx_buf.extend_from_slice(p);
        }
        self.drain_tx()
    }

    fn recv(&mut self, out: &mut Vec<u8>, timeout: Duration) -> Result<bool> {
        let deadline = Instant::now() + timeout;
        let mut bo = Backoff::new();
        loop {
            if try_extract(&mut self.accum, out)? {
                return Ok(true);
            }
            let n = self.rx.pop(&self.seg, &mut self.accum, MAX_FRAME);
            if n > 0 {
                bo.reset();
                continue;
            }
            if self.seg.peer_closed(self.is_server) {
                bail!("shm peer closed");
            }
            if Instant::now() >= deadline {
                return Ok(false);
            }
            bo.snooze();
            if bo.should_probe() {
                self.probe_liveness()?;
            }
        }
    }
}

#[cfg(unix)]
impl Drop for ShmConn {
    fn drop(&mut self) {
        self.seg.set_closed(self.is_server);
    }
}

#[cfg(unix)]
static SHM_SEG_COUNTER: AtomicU64 = AtomicU64::new(0);

// ---------------------------------------------------------------------------
// Transport trait + inproc
// ---------------------------------------------------------------------------

/// Object-safe store access over any transport.  Blocking semantics are
/// identical to [`ShardedStore`]: `Ok(None)` is a timeout, `Err` is a
/// transport failure (never used by `inproc`).
pub trait Transport: Send + Sync {
    fn kind(&self) -> &'static str;
    fn put(&self, key: &str, value: Value) -> Result<()>;
    fn get(&self, key: &str) -> Result<Option<Value>>;
    fn take(&self, key: &str) -> Result<Option<Value>>;
    fn exists(&self, key: &str) -> Result<bool>;
    fn delete(&self, key: &str) -> Result<bool>;
    fn clear(&self) -> Result<()>;
    /// `wait_for` (`take = false`) / `wait_take` (`take = true`).
    fn wait(&self, key: &str, timeout: Duration, take: bool) -> Result<Option<Value>>;
    fn wait_any(&self, keys: &[&str], timeout: Duration, take: bool)
        -> Result<Option<(usize, Value)>>;
    /// Batched put: every item lands atomically-per-key in one logical
    /// op.  Remote transports send ONE frame (chunked only if the
    /// encoding would exceed [`MAX_FRAME`]); the default is the per-key
    /// loop, so per-key and batched paths stay observably equivalent.
    fn put_many(&self, items: Vec<(String, Value)>) -> Result<()> {
        for (k, v) in items {
            self.put(&k, v)?;
        }
        Ok(())
    }
    /// Blocking batched take (see [`ShardedStore::take_many_wait`]):
    /// wait until any key is present, consume all present ones, return
    /// `(index, value)` pairs in ascending index order (empty =
    /// timeout).  One frame on remote transports.
    fn take_many(&self, keys: &[&str], timeout: Duration) -> Result<Vec<(usize, Value)>> {
        // Default: one blocking wait for the first hit, then a
        // non-blocking sweep of the rest — same observable result.
        let Some(hit) = self.wait_any(keys, timeout, true)? else {
            return Ok(Vec::new());
        };
        let mut out = vec![hit];
        for (i, k) in keys.iter().enumerate() {
            if i != out[0].0 {
                if let Some(v) = self.take(k)? {
                    out.push((i, v));
                }
            }
        }
        out.sort_unstable_by_key(|e| e.0);
        Ok(out)
    }
    /// `put` through a caller-held scratch buffer and pre-interned key
    /// (the heartbeat fast path: zero allocations per beat on remote
    /// transports).  The default ignores the scratch.
    fn put_interned(&self, scratch: &mut Vec<u8>, key: &str, value: Value) -> Result<()> {
        let _ = scratch;
        self.put(key, value)
    }
    /// A persistent tag-addressed subscription (see
    /// [`Subscription`]); remote transports pin one connection per
    /// subscription with a server-side `Subscription` behind it.
    fn subscribe(&self) -> Result<Box<dyn TransportSub>>;
}

/// Object-safe [`Subscription`] surface.
pub trait TransportSub: Send {
    fn add(&mut self, tag: usize, key: &str) -> Result<()>;
    fn remove(&mut self, tag: usize) -> Result<()>;
    fn wait_take(&mut self, timeout: Duration) -> Result<Option<(usize, Value)>>;
    /// Batched wait (see [`Subscription::wait_take_many`]): block for
    /// the first delivery, then drain up to `max - 1` more without
    /// blocking.  One frame per call on remote transports; the default
    /// degrades to a single `wait_take`.
    fn wait_take_many(&mut self, timeout: Duration, max: usize) -> Result<Vec<(usize, Value)>> {
        if max == 0 {
            return Ok(Vec::new());
        }
        Ok(self.wait_take(timeout)?.into_iter().collect())
    }
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The in-process transport: a thin veneer over [`ShardedStore`] for
/// the conformance suite and the wave benches.  The production inproc
/// path in [`crate::orchestrator::Client`] does NOT go through this
/// trait object — it calls the store directly.
pub struct InprocTransport {
    store: Arc<ShardedStore>,
}

impl InprocTransport {
    pub fn new(store: Arc<ShardedStore>) -> InprocTransport {
        InprocTransport { store }
    }
}

impl Transport for InprocTransport {
    fn kind(&self) -> &'static str {
        "inproc"
    }
    fn put(&self, key: &str, value: Value) -> Result<()> {
        self.store.put(key, value);
        Ok(())
    }
    fn get(&self, key: &str) -> Result<Option<Value>> {
        Ok(self.store.get(key))
    }
    fn take(&self, key: &str) -> Result<Option<Value>> {
        Ok(self.store.take(key))
    }
    fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.store.exists(key))
    }
    fn delete(&self, key: &str) -> Result<bool> {
        Ok(self.store.delete(key))
    }
    fn clear(&self) -> Result<()> {
        self.store.clear();
        Ok(())
    }
    fn wait(&self, key: &str, timeout: Duration, take: bool) -> Result<Option<Value>> {
        Ok(if take {
            self.store.wait_take(key, timeout)
        } else {
            self.store.wait_for(key, timeout)
        })
    }
    fn wait_any(
        &self,
        keys: &[&str],
        timeout: Duration,
        take: bool,
    ) -> Result<Option<(usize, Value)>> {
        Ok(if take {
            self.store.wait_any_take(keys, timeout)
        } else {
            self.store.wait_any(keys, timeout)
        })
    }
    fn put_many(&self, items: Vec<(String, Value)>) -> Result<()> {
        self.store.put_many(items);
        Ok(())
    }
    fn take_many(&self, keys: &[&str], timeout: Duration) -> Result<Vec<(usize, Value)>> {
        Ok(self.store.take_many_wait(keys, timeout))
    }
    fn subscribe(&self) -> Result<Box<dyn TransportSub>> {
        Ok(Box::new(InprocSub(Subscription::new(self.store.clone()))))
    }
}

struct InprocSub(Subscription);

impl TransportSub for InprocSub {
    fn add(&mut self, tag: usize, key: &str) -> Result<()> {
        self.0.add(tag, key);
        Ok(())
    }
    fn remove(&mut self, tag: usize) -> Result<()> {
        self.0.remove(tag);
        Ok(())
    }
    fn wait_take(&mut self, timeout: Duration) -> Result<Option<(usize, Value)>> {
        Ok(self.0.wait_take(timeout))
    }
    fn wait_take_many(&mut self, timeout: Duration, max: usize) -> Result<Vec<(usize, Value)>> {
        Ok(self.0.wait_take_many(timeout, max))
    }
    fn len(&self) -> usize {
        self.0.len()
    }
}

// ---------------------------------------------------------------------------
// Remote transport (tcp | shm client side)
// ---------------------------------------------------------------------------

/// Deterministic client-side fault injection (the chaos harness):
/// `relexi env-worker` builds one from the run's `[fault]` plan and
/// attaches it via [`RemoteTransport::connect_with_fault`].  A transport
/// built through plain [`RemoteTransport::connect`] carries the no-op
/// instance, so the production path pays nothing beyond a branch.
pub struct TransportFault {
    /// Abort the whole process — no unwinding, no cleanup, the closest
    /// in-tree stand-in for a node loss — once this many `put` frames
    /// have been issued.
    kill_after_puts: Option<u64>,
    /// 1-based rpc frame numbers whose first attempt fails with a
    /// synthetic connection error (exercises the retry-on-fresh-
    /// connection path without a flaky network).
    drop_frames: Vec<u64>,
    /// 1-based rpc frame numbers delayed before sending.
    delay_frames: Vec<(u64, Duration)>,
    puts: AtomicU64,
    frames: AtomicU64,
}

impl TransportFault {
    /// The no-op plan every production transport carries.
    pub fn none() -> TransportFault {
        TransportFault::new(None, Vec::new(), Vec::new())
    }

    /// A concrete plan (see field docs; counters start at zero).
    pub fn new(
        kill_after_puts: Option<u64>,
        drop_frames: Vec<u64>,
        delay_frames: Vec<(u64, Duration)>,
    ) -> TransportFault {
        TransportFault {
            kill_after_puts,
            drop_frames,
            delay_frames,
            puts: AtomicU64::new(0),
            frames: AtomicU64::new(0),
        }
    }

    /// Account one logical `put`; aborts the process at the threshold.
    fn on_put(&self) {
        if let Some(k) = self.kill_after_puts {
            let n = self.puts.fetch_add(1, Ordering::Relaxed) + 1;
            if n >= k {
                crate::tlog!(warn, "[fault] killput: aborting process after {n} puts");
                std::process::abort();
            }
        }
    }

    /// Account one rpc frame; sleeps out any configured delay and
    /// returns whether this frame's first attempt must fail.
    fn on_frame(&self) -> bool {
        if self.drop_frames.is_empty() && self.delay_frames.is_empty() {
            return false;
        }
        let n = self.frames.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(&(_, d)) = self.delay_frames.iter().find(|&&(f, _)| f == n) {
            std::thread::sleep(d);
        }
        self.drop_frames.contains(&n)
    }
}

/// Client side of the `tcp` and `shm` transports: a connection pool of
/// framed pipes to one [`ExchangeServer`].  Each op checks a connection
/// out (dialing a fresh one if the pool is empty), so concurrent
/// blocking ops from different worker threads never serialize on one
/// socket.  An op that hits an I/O error retries exactly once on a
/// fresh connection (and every dial runs under the shared
/// [`RetryPolicy`] backoff), then reports the failure.
pub struct RemoteTransport {
    kind: &'static str,
    addr: String,
    connect_retries: u32,
    fault: TransportFault,
    pool: Mutex<Vec<Box<dyn Conn>>>,
    /// The persistent per-worker data connection: quick (non-blocking)
    /// ops and batched bursts ride one long-lived pipe instead of
    /// checking a connection out of the pool per op.  `try_lock` only —
    /// a contended quick op falls back to the pooled path rather than
    /// serializing, and blocking ops (`wait`/`wait_any`/`take_many`)
    /// never use it, so a server-side wait can't wedge the data plane.
    data: Mutex<Option<Box<dyn Conn>>>,
}

impl RemoteTransport {
    /// Dial an exchange.  `kind` is `"tcp"` or `"shm"`; `addr` is the
    /// server's TCP address either way (shm bootstraps over it).
    /// Validates reachability by dialing one connection eagerly under
    /// [`RetryPolicy::dial`]: `connect_retries + 1` attempts with capped
    /// exponential backoff and jitter (a worker process racing its
    /// trainer's bind), deadline-bounded.
    pub fn connect(kind: &str, addr: &str, connect_retries: u32) -> Result<Arc<RemoteTransport>> {
        RemoteTransport::connect_with_fault(kind, addr, connect_retries, TransportFault::none())
    }

    /// [`RemoteTransport::connect`] with a fault-injection plan attached
    /// (see [`TransportFault`]).
    pub fn connect_with_fault(
        kind: &str,
        addr: &str,
        connect_retries: u32,
        fault: TransportFault,
    ) -> Result<Arc<RemoteTransport>> {
        let kind = match kind {
            "tcp" => "tcp",
            "shm" => "shm",
            other => bail!("unknown remote transport {other:?} (tcp|shm)"),
        };
        let t = Arc::new(RemoteTransport {
            kind,
            addr: addr.to_string(),
            connect_retries,
            fault,
            pool: Mutex::new(Vec::new()),
            data: Mutex::new(None),
        });
        let c = t.dial()?;
        t.pool.lock().unwrap().push(c);
        Ok(t)
    }

    /// Dial one connection under the shared retry policy.  The error is
    /// structured: attempts made, elapsed time, last underlying error.
    fn dial(&self) -> Result<Box<dyn Conn>> {
        let what = format!("dial {} exchange at {}", self.kind, self.addr);
        let conn = RetryPolicy::dial(self.connect_retries).run(&what, |_| self.dial_once())?;
        Ok(conn)
    }

    fn dial_once(&self) -> Result<Box<dyn Conn>> {
        let stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connect {}", self.addr))?;
        let tcp = TcpConn::new(stream)?;
        match self.kind {
            "tcp" => Ok(Box::new(tcp)),
            "shm" => self.upgrade_to_shm(tcp),
            _ => unreachable!(),
        }
    }

    #[cfg(unix)]
    fn upgrade_to_shm(&self, mut tcp: TcpConn) -> Result<Box<dyn Conn>> {
        let path = std::env::temp_dir().join(format!(
            "relexi-shm-{}-{}.seg",
            std::process::id(),
            SHM_SEG_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let seg = shm::Seg::create(&path, SHM_RING_BYTES)?;
        let mut buf = Vec::new();
        Request::ShmOpen {
            path: path.to_string_lossy().into_owned(),
            ring_bytes: SHM_RING_BYTES as u64,
        }
        .encode_into(&mut buf);
        let frame = buf.clone();
        tcp.send(&frame)?;
        let got = tcp.recv(&mut buf, RPC_TIMEOUT)?;
        // The segment file can be unlinked as soon as the server has
        // mapped it (or failed to): both mappings outlive the name.
        let _ = std::fs::remove_file(&path);
        ensure!(got, "shm upgrade handshake timed out");
        match Response::decode(&buf)? {
            Response::Unit => {}
            Response::Error(msg) => bail!("server refused shm upgrade: {msg}"),
            other => bail!("unexpected shm upgrade reply {other:?}"),
        }
        Ok(Box::new(ShmConn::new(seg, SHM_RING_BYTES, false, tcp.into_stream()?)?))
    }

    #[cfg(not(unix))]
    fn upgrade_to_shm(&self, _tcp: TcpConn) -> Result<Box<dyn Conn>> {
        bail!("the shm transport requires a unix platform (mmap)")
    }

    fn checkout(&self) -> Result<Box<dyn Conn>> {
        if let Some(c) = self.pool.lock().unwrap().pop() {
            return Ok(c);
        }
        self.dial()
    }

    /// One request/response round trip with single-retry-on-fresh-
    /// connection semantics (at-most-once against the server: the
    /// retry only fires when the first attempt failed to produce a
    /// response).  The redial inside the retry runs under the shared
    /// [`RetryPolicy`] backoff, so a restarting exchange is waited out
    /// instead of failed fast.
    fn rpc(&self, req: &Request, deadline: Duration) -> Result<Response> {
        let drop_first = self.fault.on_frame();
        self.rpc_pooled(req, deadline, drop_first)
    }

    /// [`Self::rpc`] on the persistent data connection (dialed lazily,
    /// replaced on error).  Contention or a faulted pipe falls back to
    /// the pooled path, so quick ops are never slower than the per-op
    /// checkout pattern they replace.
    fn rpc_quick(&self, req: &Request, deadline: Duration) -> Result<Response> {
        let drop_first = self.fault.on_frame();
        if !drop_first {
            if let Ok(mut slot) = self.data.try_lock() {
                if slot.is_none() {
                    if let Ok(c) = self.dial() {
                        *slot = Some(c);
                    }
                }
                if let Some(conn) = slot.as_mut() {
                    let mut frame = Vec::new();
                    req.encode_into(&mut frame);
                    crate::tevent!("net.send", frame.len());
                    match Self::rpc_on(conn, &frame, deadline) {
                        Ok(resp) => return Ok(resp),
                        Err(_) => *slot = None, // dead pipe: retry pooled below
                    }
                }
            }
        }
        self.rpc_pooled(req, deadline, drop_first)
    }

    fn rpc_pooled(&self, req: &Request, deadline: Duration, mut drop_first: bool) -> Result<Response> {
        let mut frame = Vec::new();
        req.encode_into(&mut frame);
        crate::tevent!("net.send", frame.len());
        let mut last = None;
        for attempt in 0..2 {
            // First attempt reuses a pooled connection; the retry always
            // dials fresh (the pooled one just failed).
            let conn = if attempt == 0 { self.checkout() } else { self.dial() };
            let mut conn = match conn {
                Ok(c) => c,
                Err(e) => {
                    last = Some(e);
                    continue;
                }
            };
            if drop_first {
                // Injected fault: discard the connection before the
                // send, exactly as a real connection failure would.
                drop_first = false;
                last = Some(anyhow::anyhow!("injected frame drop (fault plan)"));
                continue;
            }
            match Self::rpc_on(&mut conn, &frame, deadline) {
                Ok(resp) => {
                    self.pool.lock().unwrap().push(conn);
                    return Ok(resp);
                }
                Err(e) => last = Some(e), // conn dropped; retry fresh
            }
        }
        Err(last.unwrap().context(format!("{} exchange rpc failed", self.kind)))
    }

    fn rpc_on(conn: &mut Box<dyn Conn>, frame: &[u8], deadline: Duration) -> Result<Response> {
        conn.send(frame)?;
        let mut buf = Vec::new();
        ensure!(
            conn.recv(&mut buf, deadline)?,
            "exchange did not answer within {deadline:?}"
        );
        Response::decode(&buf)
    }

    /// Burst-send pre-encoded frames and collect their pipelined
    /// responses in order (one vectored write on tcp, one ring pass on
    /// shm).
    fn burst_on(conn: &mut Box<dyn Conn>, frames: &[Vec<u8>], deadline: Duration) -> Result<Vec<Response>> {
        crate::tevent!("net.send_burst", frames.iter().map(|f| f.len()).sum::<usize>());
        let refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
        conn.send_many(&refs)?;
        let mut out = Vec::with_capacity(frames.len());
        let mut buf = Vec::new();
        for _ in frames {
            ensure!(
                conn.recv(&mut buf, deadline)?,
                "exchange did not answer within {deadline:?}"
            );
            out.push(Response::decode(&buf)?);
        }
        Ok(out)
    }

    /// A burst with the same retry shape as [`Self::rpc_quick`]:
    /// persistent data connection first, then the pooled
    /// single-retry-on-fresh-connection path.  Only idempotent frames
    /// (puts) may ride a burst — a whole-burst retry re-applies them
    /// harmlessly.
    fn burst(&self, frames: &[Vec<u8>]) -> Result<Vec<Response>> {
        let mut drop_first = self.fault.on_frame();
        if !drop_first {
            if let Ok(mut slot) = self.data.try_lock() {
                if slot.is_none() {
                    if let Ok(c) = self.dial() {
                        *slot = Some(c);
                    }
                }
                if let Some(conn) = slot.as_mut() {
                    match Self::burst_on(conn, frames, RPC_TIMEOUT) {
                        Ok(r) => return Ok(r),
                        Err(_) => *slot = None,
                    }
                }
            }
        }
        let mut last = None;
        for attempt in 0..2 {
            let conn = if attempt == 0 { self.checkout() } else { self.dial() };
            let mut conn = match conn {
                Ok(c) => c,
                Err(e) => {
                    last = Some(e);
                    continue;
                }
            };
            if drop_first {
                drop_first = false;
                last = Some(anyhow::anyhow!("injected frame drop (fault plan)"));
                continue;
            }
            match Self::burst_on(&mut conn, frames, RPC_TIMEOUT) {
                Ok(r) => {
                    self.pool.lock().unwrap().push(conn);
                    return Ok(r);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap().context(format!("{} exchange burst failed", self.kind)))
    }
}

fn ms(timeout: Duration) -> u64 {
    timeout.as_millis().min(u64::MAX as u128) as u64
}

fn expect_unit(resp: Response) -> Result<()> {
    match resp {
        Response::Unit => Ok(()),
        Response::Error(msg) => bail!("exchange error: {msg}"),
        other => bail!("unexpected exchange reply {other:?}"),
    }
}

fn expect_bool(resp: Response) -> Result<bool> {
    match resp {
        Response::Bool(b) => Ok(b),
        Response::Error(msg) => bail!("exchange error: {msg}"),
        other => bail!("unexpected exchange reply {other:?}"),
    }
}

fn expect_maybe(resp: Response) -> Result<Option<Value>> {
    match resp {
        Response::Maybe(v) => Ok(v),
        Response::Error(msg) => bail!("exchange error: {msg}"),
        other => bail!("unexpected exchange reply {other:?}"),
    }
}

fn expect_hit(resp: Response) -> Result<Option<(usize, Value)>> {
    match resp {
        Response::Hit(h) => Ok(h.map(|(i, v)| (i as usize, v))),
        Response::Error(msg) => bail!("exchange error: {msg}"),
        other => bail!("unexpected exchange reply {other:?}"),
    }
}

fn expect_many(resp: Response) -> Result<Vec<(usize, Value)>> {
    match resp {
        Response::Many(hits) => Ok(hits.into_iter().map(|(i, v)| (i as usize, v)).collect()),
        Response::Error(msg) => bail!("exchange error: {msg}"),
        other => bail!("unexpected exchange reply {other:?}"),
    }
}

impl Transport for RemoteTransport {
    fn kind(&self) -> &'static str {
        self.kind
    }
    fn put(&self, key: &str, value: Value) -> Result<()> {
        self.fault.on_put();
        expect_unit(self.rpc_quick(&Request::Put { key: key.to_string(), value }, RPC_TIMEOUT)?)
    }
    fn get(&self, key: &str) -> Result<Option<Value>> {
        expect_maybe(self.rpc_quick(&Request::Get { key: key.to_string() }, RPC_TIMEOUT)?)
    }
    fn take(&self, key: &str) -> Result<Option<Value>> {
        expect_maybe(self.rpc_quick(&Request::Take { key: key.to_string() }, RPC_TIMEOUT)?)
    }
    fn exists(&self, key: &str) -> Result<bool> {
        expect_bool(self.rpc_quick(&Request::Exists { key: key.to_string() }, RPC_TIMEOUT)?)
    }
    fn delete(&self, key: &str) -> Result<bool> {
        expect_bool(self.rpc_quick(&Request::Delete { key: key.to_string() }, RPC_TIMEOUT)?)
    }
    fn clear(&self) -> Result<()> {
        expect_unit(self.rpc(&Request::Clear, RPC_TIMEOUT)?)
    }
    fn wait(&self, key: &str, timeout: Duration, take: bool) -> Result<Option<Value>> {
        let req = Request::Wait { key: key.to_string(), timeout_ms: ms(timeout), take };
        expect_maybe(self.rpc(&req, timeout + RPC_GRACE)?)
    }
    fn wait_any(
        &self,
        keys: &[&str],
        timeout: Duration,
        take: bool,
    ) -> Result<Option<(usize, Value)>> {
        let req = Request::WaitAny {
            keys: keys.iter().map(|k| k.to_string()).collect(),
            timeout_ms: ms(timeout),
            take,
        };
        expect_hit(self.rpc(&req, timeout + RPC_GRACE)?)
    }
    fn put_many(&self, items: Vec<(String, Value)>) -> Result<()> {
        if items.is_empty() {
            return Ok(());
        }
        for _ in 0..items.len() {
            self.fault.on_put();
        }
        // Chunk so every encoded frame stays within MAX_FRAME (a lone
        // item is bounded exactly like a plain Put, so a singleton
        // chunk is always legal), then send the chunks as one burst.
        let mut frames: Vec<Vec<u8>> = Vec::new();
        let mut chunk: Vec<(String, Value)> = Vec::new();
        let mut chunk_bytes = 0usize;
        for (k, v) in items {
            let cost = k.len() + v.size_bytes() + 64;
            if !chunk.is_empty() && (chunk_bytes + cost > MAX_PAYLOAD || chunk.len() >= 1 << 16) {
                let mut f = Vec::new();
                Request::PutMany { items: std::mem::take(&mut chunk) }.encode_into(&mut f);
                frames.push(f);
                chunk_bytes = 0;
            }
            chunk_bytes += cost;
            chunk.push((k, v));
        }
        if !chunk.is_empty() {
            let mut f = Vec::new();
            Request::PutMany { items: chunk }.encode_into(&mut f);
            frames.push(f);
        }
        for resp in self.burst(&frames)? {
            expect_unit(resp)?;
        }
        Ok(())
    }
    fn take_many(&self, keys: &[&str], timeout: Duration) -> Result<Vec<(usize, Value)>> {
        let req = Request::TakeMany {
            keys: keys.iter().map(|k| k.to_string()).collect(),
            timeout_ms: ms(timeout),
        };
        expect_many(self.rpc(&req, timeout + RPC_GRACE)?)
    }
    fn put_interned(&self, scratch: &mut Vec<u8>, key: &str, value: Value) -> Result<()> {
        self.fault.on_put();
        let drop_first = self.fault.on_frame();
        // Encode a Put frame straight into the caller's scratch — no
        // String key, no fresh frame buffer, so a steady-state caller
        // (the heartbeat thread) allocates nothing per call.
        scratch.clear();
        scratch.push(1); // Request::Put opcode
        wire::w_str(scratch, key);
        value.encode_into(scratch);
        if !drop_first {
            if let Ok(mut slot) = self.data.try_lock() {
                if slot.is_none() {
                    if let Ok(c) = self.dial() {
                        *slot = Some(c);
                    }
                }
                if let Some(conn) = slot.as_mut() {
                    match Self::rpc_on(conn, scratch, RPC_TIMEOUT) {
                        Ok(resp) => return expect_unit(resp),
                        Err(_) => *slot = None,
                    }
                }
            }
        }
        // Cold path (contended / dead pipe): pooled retry.
        expect_unit(self.rpc_pooled(
            &Request::Put { key: key.to_string(), value },
            RPC_TIMEOUT,
            drop_first,
        )?)
    }
    fn subscribe(&self) -> Result<Box<dyn TransportSub>> {
        Ok(Box::new(RemoteSub {
            conn: self.dial()?,
            tags: std::collections::HashSet::new(),
        }))
    }
}

/// A remote subscription pins one connection: the server keeps the
/// matching [`Subscription`] alive for exactly that connection's
/// lifetime, so add/remove deltas and delivered-exactly-once hits ride
/// the store's own guarantees.  No transparent reconnect here — a lost
/// connection would silently lose registrations, so it surfaces as an
/// error instead.
struct RemoteSub {
    conn: Box<dyn Conn>,
    tags: std::collections::HashSet<usize>,
}

impl RemoteSub {
    fn rpc(&mut self, req: &Request, deadline: Duration) -> Result<Response> {
        let mut frame = Vec::new();
        req.encode_into(&mut frame);
        RemoteTransport::rpc_on(&mut self.conn, &frame, deadline)
    }
}

impl TransportSub for RemoteSub {
    fn add(&mut self, tag: usize, key: &str) -> Result<()> {
        expect_unit(self.rpc(
            &Request::SubAdd { tag: tag as u64, key: key.to_string() },
            RPC_TIMEOUT,
        )?)?;
        self.tags.insert(tag);
        Ok(())
    }
    fn remove(&mut self, tag: usize) -> Result<()> {
        expect_unit(self.rpc(&Request::SubRemove { tag: tag as u64 }, RPC_TIMEOUT)?)?;
        self.tags.remove(&tag);
        Ok(())
    }
    fn wait_take(&mut self, timeout: Duration) -> Result<Option<(usize, Value)>> {
        let req = Request::SubWait { timeout_ms: ms(timeout) };
        expect_hit(self.rpc(&req, timeout + RPC_GRACE)?)
    }
    fn wait_take_many(&mut self, timeout: Duration, max: usize) -> Result<Vec<(usize, Value)>> {
        if max == 0 {
            return Ok(Vec::new());
        }
        let req = Request::SubWaitMany {
            timeout_ms: ms(timeout),
            max: max.min(1 << 16) as u32,
        };
        expect_many(self.rpc(&req, timeout + RPC_GRACE)?)
    }
    fn len(&self) -> usize {
        self.tags.len()
    }
}

// ---------------------------------------------------------------------------
// Exchange server
// ---------------------------------------------------------------------------

/// The network face of a [`ShardedStore`]: a nonblocking accept loop
/// plus one handler thread per connection.  Lives in the trainer
/// process next to the authoritative store; dropped, it stops
/// accepting, disconnects every peer and joins all handlers.
pub struct ExchangeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ExchangeServer {
    /// Bind and start serving `store` on `bind` (e.g. `127.0.0.1:0`).
    pub fn bind(store: Arc<ShardedStore>, bind: &str) -> Result<ExchangeServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("bind exchange on {bind}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept = std::thread::Builder::new()
            .name("exchange-accept".into())
            .spawn(move || accept_loop(listener, store, stop2))
            .context("spawn exchange accept loop")?;
        Ok(ExchangeServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (port resolved if `:0` was requested).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ExchangeServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, store: Arc<ShardedStore>, stop: Arc<AtomicBool>) {
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let store = store.clone();
                let stop = stop.clone();
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                match std::thread::Builder::new()
                    .name("exchange-conn".into())
                    .spawn(move || serve_conn(stream, store, stop))
                {
                    Ok(h) => handlers.push(h),
                    Err(e) => crate::tlog!(error, "exchange: spawn handler failed: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
        handlers.retain(|h| !h.is_finished());
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// Per-connection server state: a plain TCP pipe, possibly upgraded to
/// shm rings mid-stream.
enum ServerConn {
    Tcp(TcpConn),
    #[cfg(unix)]
    Shm(ShmConn),
}

impl ServerConn {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        match self {
            ServerConn::Tcp(c) => c.send(payload),
            #[cfg(unix)]
            ServerConn::Shm(c) => c.send(payload),
        }
    }
    fn recv(&mut self, out: &mut Vec<u8>, timeout: Duration) -> Result<bool> {
        match self {
            ServerConn::Tcp(c) => c.recv(out, timeout),
            #[cfg(unix)]
            ServerConn::Shm(c) => c.recv(out, timeout),
        }
    }
}

fn serve_conn(stream: TcpStream, store: Arc<ShardedStore>, stop: Arc<AtomicBool>) {
    let tcp = match TcpConn::new(stream) {
        Ok(c) => c,
        Err(_) => return,
    };
    // Disconnects are routine (worker exit, trainer teardown): they end
    // the handler quietly.  Protocol violations get a stderr line.
    if let Err(e) = serve_conn_inner(ServerConn::Tcp(tcp), store, stop) {
        let msg = format!("{e:#}");
        if !msg.contains("connection closed") && !msg.contains("peer closed") {
            crate::tlog!(warn, "exchange: connection error: {msg}");
        }
    }
}

/// Control-plane key prefix (heartbeats, hello/begin/stop handshakes)
/// exempt from the data-plane frame counter.
const CTL_PREFIX: &str = "__relexi:ctl:";

fn is_ctl(key: &str) -> bool {
    key.starts_with(CTL_PREFIX)
}

/// Should this request bump [`crate::orchestrator::store::StoreStats::frames`]?
/// Connection management and pure control-plane traffic are exempt so
/// the counter isolates the rollout data exchange — the O(W·T)
/// frames-per-wave CI invariant.
fn counts_as_data_frame(req: &Request) -> bool {
    match req {
        Request::Bye | Request::ShmOpen { .. } | Request::Clear => false,
        Request::Put { key, .. }
        | Request::Get { key }
        | Request::Take { key }
        | Request::Exists { key }
        | Request::Delete { key }
        | Request::Wait { key, .. }
        | Request::SubAdd { key, .. } => !is_ctl(key),
        Request::WaitAny { keys, .. } | Request::TakeMany { keys, .. } => {
            !keys.iter().all(|k| is_ctl(k))
        }
        Request::PutMany { items } => !items.iter().all(|(k, _)| is_ctl(k)),
        Request::SubRemove { .. } | Request::SubWait { .. } | Request::SubWaitMany { .. } => true,
    }
}

/// Record one telemetry instant per served data frame, named by request
/// kind, with the wire size as payload.  Called at exactly the
/// [`counts_as_data_frame`] site, so in a merged trace the per-wave frame
/// event count equals `StoreStats.frames` by construction.  Each arm is its
/// own macro expansion so the name interning stays per-site static (no
/// locks, no allocation on the hot path).
fn record_frame_event(req: &Request, bytes: usize) {
    match req {
        Request::Put { .. } => crate::tevent!("frame.put", bytes),
        Request::Get { .. } => crate::tevent!("frame.get", bytes),
        Request::Take { .. } => crate::tevent!("frame.take", bytes),
        Request::Exists { .. } => crate::tevent!("frame.exists", bytes),
        Request::Delete { .. } => crate::tevent!("frame.delete", bytes),
        Request::Wait { .. } => crate::tevent!("frame.wait", bytes),
        Request::WaitAny { .. } => crate::tevent!("frame.wait_any", bytes),
        Request::SubAdd { .. } => crate::tevent!("frame.sub_add", bytes),
        Request::SubRemove { .. } => crate::tevent!("frame.sub_remove", bytes),
        Request::SubWait { .. } => crate::tevent!("frame.sub_wait", bytes),
        Request::SubWaitMany { .. } => crate::tevent!("frame.sub_wait_many", bytes),
        Request::PutMany { .. } => crate::tevent!("frame.put_many", bytes),
        Request::TakeMany { .. } => crate::tevent!("frame.take_many", bytes),
        Request::Bye | Request::ShmOpen { .. } | Request::Clear => {}
    }
}

fn serve_conn_inner(
    mut conn: ServerConn,
    store: Arc<ShardedStore>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut sub: Option<Subscription> = None;
    let mut req_buf = Vec::new();
    let mut resp_buf = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        if !conn.recv(&mut req_buf, SLICE)? {
            continue;
        }
        let req = match Request::decode(&req_buf) {
            Ok(r) => r,
            Err(e) => {
                // Tell the peer why, then drop the connection: after a
                // framing/codec violation nothing downstream is
                // trustworthy.
                resp_buf.clear();
                Response::Error(format!("bad request frame: {e:#}")).encode_into(&mut resp_buf);
                let _ = conn.send(&resp_buf);
                bail!("bad request frame: {e:#}");
            }
        };
        if counts_as_data_frame(&req) {
            store.note_frame();
            record_frame_event(&req, req_buf.len());
        }
        // The shm upgrade swaps the pipe itself, so it is handled
        // outside the plain request->response match.
        if let Request::ShmOpen { path, ring_bytes } = &req {
            conn = upgrade_conn(conn, path, *ring_bytes, &mut resp_buf)?;
            continue;
        }
        let resp = match req {
            Request::Put { key, value } => {
                store.put(key.as_str(), value);
                Response::Unit
            }
            Request::Get { key } => Response::Maybe(store.get(key.as_str())),
            Request::Take { key } => Response::Maybe(store.take(key.as_str())),
            Request::Exists { key } => Response::Bool(store.exists(key.as_str())),
            Request::Delete { key } => Response::Bool(store.delete(key.as_str())),
            Request::Clear => {
                store.clear();
                Response::Unit
            }
            Request::Wait { key, timeout_ms, take } => Response::Maybe(sliced_wait(
                timeout_ms,
                &stop,
                |slice| {
                    if take {
                        store.wait_take(key.as_str(), slice)
                    } else {
                        store.wait_for(key.as_str(), slice)
                    }
                },
            )),
            Request::WaitAny { keys, timeout_ms, take } => {
                let refs: Vec<&str> = keys.iter().map(String::as_str).collect();
                let hit = sliced_wait(timeout_ms, &stop, |slice| {
                    if take {
                        store.wait_any_take(&refs, slice)
                    } else {
                        store.wait_any(&refs, slice)
                    }
                });
                Response::Hit(hit.map(|(i, v)| (i as u64, v)))
            }
            Request::SubAdd { tag, key } => {
                sub.get_or_insert_with(|| Subscription::new(store.clone()))
                    .add(tag as usize, key.as_str());
                Response::Unit
            }
            Request::SubRemove { tag } => {
                match &mut sub {
                    Some(s) => {
                        s.remove(tag as usize);
                        Response::Unit
                    }
                    None => Response::Error("no subscription on this connection".into()),
                }
            }
            Request::SubWait { timeout_ms } => match &mut sub {
                Some(s) => {
                    let hit = sliced_wait(timeout_ms, &stop, |slice| s.wait_take(slice));
                    Response::Hit(hit.map(|(t, v)| (t as u64, v)))
                }
                None => Response::Error("no subscription on this connection".into()),
            },
            Request::PutMany { items } => {
                store.put_many(items);
                Response::Unit
            }
            Request::TakeMany { keys, timeout_ms } => {
                let refs: Vec<&str> = keys.iter().map(String::as_str).collect();
                let hits = sliced_wait(timeout_ms, &stop, |slice| {
                    // Each inner grouped take is atomic, so slicing
                    // never splits or double-delivers a batch.
                    let got = store.take_many_wait(&refs, slice);
                    if got.is_empty() {
                        None
                    } else {
                        Some(got)
                    }
                })
                .unwrap_or_default();
                Response::Many(hits.into_iter().map(|(i, v)| (i as u64, v)).collect())
            }
            Request::SubWaitMany { timeout_ms, max } => match &mut sub {
                Some(s) => {
                    let hits = sliced_wait(timeout_ms, &stop, |slice| {
                        let got = s.wait_take_many(slice, max as usize);
                        if got.is_empty() {
                            None
                        } else {
                            Some(got)
                        }
                    })
                    .unwrap_or_default();
                    Response::Many(hits.into_iter().map(|(t, v)| (t as u64, v)).collect())
                }
                None => Response::Error("no subscription on this connection".into()),
            },
            Request::Bye => return Ok(()),
            Request::ShmOpen { .. } => unreachable!("handled above"),
        };
        resp_buf.clear();
        resp.encode_into(&mut resp_buf);
        conn.send(&resp_buf)?;
    }
}

/// Run a blocking store op in bounded slices so server shutdown is
/// observed within [`SLICE`].  Each inner call is atomic, so a value is
/// consumed iff it is returned — slicing preserves exactly-once.
fn sliced_wait<T>(
    timeout_ms: u64,
    stop: &AtomicBool,
    mut op: impl FnMut(Duration) -> Option<T>,
) -> Option<T> {
    let deadline = Instant::now() + Duration::from_millis(timeout_ms);
    loop {
        if stop.load(Ordering::SeqCst) {
            return None;
        }
        let now = Instant::now();
        let left = deadline.saturating_duration_since(now);
        let slice = left.min(SLICE).max(Duration::from_millis(1));
        if let Some(v) = op(slice) {
            return Some(v);
        }
        if Instant::now() >= deadline {
            return None;
        }
    }
}

#[cfg(unix)]
fn upgrade_conn(
    conn: ServerConn,
    path: &str,
    ring_bytes: u64,
    resp_buf: &mut Vec<u8>,
) -> Result<ServerConn> {
    let fail = |conn: &mut ServerConn, resp_buf: &mut Vec<u8>, msg: String| {
        resp_buf.clear();
        Response::Error(msg.clone()).encode_into(resp_buf);
        let _ = conn.send(resp_buf);
        anyhow::anyhow!("shm upgrade refused: {msg}")
    };
    let mut conn = conn;
    let ServerConn::Tcp(tcp) = conn else {
        bail!("shm upgrade on an already-upgraded connection");
    };
    conn = ServerConn::Tcp(tcp);
    if !(4096..=(1 << 30)).contains(&(ring_bytes as usize)) {
        return Err(fail(&mut conn, resp_buf, format!("bad ring_bytes {ring_bytes}")));
    }
    let seg = match shm::Seg::open(std::path::Path::new(path), ring_bytes as usize) {
        Ok(s) => s,
        Err(e) => return Err(fail(&mut conn, resp_buf, format!("{e:#}"))),
    };
    let ServerConn::Tcp(mut tcp) = conn else { unreachable!() };
    resp_buf.clear();
    Response::Unit.encode_into(resp_buf);
    tcp.send(resp_buf)?;
    let stream = tcp.into_stream()?;
    Ok(ServerConn::Shm(ShmConn::new(seg, ring_bytes as usize, true, stream)?))
}

#[cfg(not(unix))]
fn upgrade_conn(
    mut conn: ServerConn,
    _path: &str,
    _ring_bytes: u64,
    resp_buf: &mut Vec<u8>,
) -> Result<ServerConn> {
    resp_buf.clear();
    Response::Error("shm transport requires a unix platform".into()).encode_into(resp_buf);
    let _ = conn.send(resp_buf);
    bail!("shm upgrade refused: non-unix platform");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn round_trip_req(req: Request) {
        let mut buf = Vec::new();
        req.encode_into(&mut buf);
        assert_eq!(Request::decode(&buf).unwrap(), req);
        // Every truncation errors, never panics.
        for cut in 0..buf.len() {
            assert!(Request::decode(&buf[..cut]).is_err(), "{req:?} cut {cut}");
        }
        // Trailing garbage errors.
        buf.push(0);
        assert!(Request::decode(&buf).is_err(), "{req:?} trailing");
    }

    #[test]
    fn request_codec_round_trips_every_variant() {
        round_trip_req(Request::Put {
            key: "a:b".into(),
            value: Value::tensor(vec![2], vec![1.0, 2.0]),
        });
        round_trip_req(Request::Get { key: "k".into() });
        round_trip_req(Request::Take { key: "k".into() });
        round_trip_req(Request::Exists { key: "".into() });
        round_trip_req(Request::Delete { key: "k".into() });
        round_trip_req(Request::Clear);
        round_trip_req(Request::Wait { key: "k".into(), timeout_ms: 12, take: true });
        round_trip_req(Request::WaitAny {
            keys: vec!["a".into(), "b".into(), "c".into()],
            timeout_ms: u64::MAX,
            take: false,
        });
        round_trip_req(Request::SubAdd { tag: 7, key: "k".into() });
        round_trip_req(Request::SubRemove { tag: u64::MAX });
        round_trip_req(Request::SubWait { timeout_ms: 0 });
        round_trip_req(Request::Bye);
        round_trip_req(Request::ShmOpen { path: "/tmp/x.seg".into(), ring_bytes: 1 << 20 });
        round_trip_req(Request::PutMany { items: vec![] });
        round_trip_req(Request::PutMany {
            items: vec![
                ("a".into(), Value::Scalar(1.5)),
                ("b".into(), Value::tensor(vec![2], vec![3.0, 4.0])),
                ("".into(), Value::Flag(false)),
            ],
        });
        round_trip_req(Request::TakeMany { keys: vec![], timeout_ms: 0 });
        round_trip_req(Request::TakeMany {
            keys: vec!["x".into(), "y".into()],
            timeout_ms: u64::MAX,
        });
        round_trip_req(Request::SubWaitMany { timeout_ms: 250, max: u32::MAX });
    }

    fn round_trip_resp(resp: Response) {
        let mut buf = Vec::new();
        resp.encode_into(&mut buf);
        assert_eq!(Response::decode(&buf).unwrap(), resp);
        for cut in 0..buf.len() {
            assert!(Response::decode(&buf[..cut]).is_err(), "{resp:?} cut {cut}");
        }
        buf.push(0);
        assert!(Response::decode(&buf).is_err(), "{resp:?} trailing");
    }

    #[test]
    fn response_codec_round_trips_every_variant() {
        round_trip_resp(Response::Unit);
        round_trip_resp(Response::Bool(true));
        round_trip_resp(Response::Bool(false));
        round_trip_resp(Response::Maybe(None));
        round_trip_resp(Response::Maybe(Some(Value::Scalar(1.5))));
        round_trip_resp(Response::Maybe(Some(Value::tensor(vec![1, 3], vec![0.0; 3]))));
        round_trip_resp(Response::Hit(None));
        round_trip_resp(Response::Hit(Some((42, Value::Flag(true)))));
        round_trip_resp(Response::Many(vec![]));
        round_trip_resp(Response::Many(vec![
            (0, Value::Scalar(-2.5)),
            (u64::MAX, Value::tensor(vec![1, 2], vec![5.0, 6.0])),
        ]));
        round_trip_resp(Response::Error("boom".into()));
    }

    #[test]
    fn long_error_messages_are_bounded_on_char_boundaries() {
        let msg = "é".repeat(2000);
        let mut buf = Vec::new();
        Response::Error(msg).encode_into(&mut buf);
        match Response::decode(&buf).unwrap() {
            Response::Error(m) => assert!(m.len() <= 512 && !m.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frame_length_prefix_is_validated() {
        assert!(frame_len(0u32.to_le_bytes()).is_err(), "empty frame rejected");
        assert!(frame_len(u32::MAX.to_le_bytes()).is_err(), "oversized rejected");
        assert_eq!(frame_len(5u32.to_le_bytes()).unwrap(), 5);

        // An oversized prefix poisons the pipe before any allocation.
        let mut accum = u32::MAX.to_le_bytes().to_vec();
        let mut out = Vec::new();
        assert!(try_extract(&mut accum, &mut out).is_err());
    }

    #[test]
    fn tcp_transport_serves_the_store_contract_end_to_end() {
        let store = Arc::new(ShardedStore::new(4));
        let server = ExchangeServer::bind(store.clone(), "127.0.0.1:0").unwrap();
        let t = RemoteTransport::connect("tcp", &server.addr().to_string(), 1).unwrap();

        t.put("k", Value::Scalar(2.5)).unwrap();
        assert_eq!(t.get("k").unwrap().unwrap().as_scalar(), Some(2.5));
        assert!(t.exists("k").unwrap());
        assert_eq!(t.take("k").unwrap().unwrap().as_scalar(), Some(2.5));
        assert!(!t.exists("k").unwrap());
        assert!(t.get("k").unwrap().is_none());

        // Blocking wait resolved by a later put through the store side.
        let store2 = store.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            store2.put("w", Value::Flag(true));
        });
        let v = t.wait("w", Duration::from_secs(5), true).unwrap().unwrap();
        assert_eq!(v.as_flag(), Some(true));
        h.join().unwrap();
        assert!(t.get("w").unwrap().is_none(), "wait_take consumed");

        // wait_any index semantics.
        t.put("b", Value::Scalar(1.0)).unwrap();
        let (idx, _) = t
            .wait_any(&["a", "b"], Duration::from_millis(100), false)
            .unwrap()
            .unwrap();
        assert_eq!(idx, 1);

        // Subscription deltas.
        let mut sub = t.subscribe().unwrap();
        sub.add(3, "sub:x").unwrap();
        assert_eq!(sub.len(), 1);
        t.put("sub:x", Value::Scalar(9.0)).unwrap();
        let (tag, v) = sub.wait_take(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!((tag, v.as_scalar()), (3, Some(9.0)));
        sub.remove(3).unwrap();
        assert_eq!(sub.len(), 0);

        // Batched ops: one PutMany frame + one TakeMany frame, grouped
        // server-side, exactly-once per key.
        let f0 = store.stats().frames;
        t.put_many(vec![
            ("m:0".into(), Value::Scalar(1.0)),
            ("m:1".into(), Value::Scalar(2.0)),
            ("m:2".into(), Value::Scalar(3.0)),
        ])
        .unwrap();
        let hits = t.take_many(&["m:0", "m:1", "m:2"], Duration::from_secs(5)).unwrap();
        assert_eq!(hits.len(), 3);
        assert_eq!(hits.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(!t.exists("m:1").unwrap(), "take_many consumed");
        assert_eq!(store.stats().frames - f0, 3, "PutMany + TakeMany + exists frames");
        assert!(store.stats().batched_keys >= 6);

        // Control-plane puts (heartbeats) are exempt from the
        // data-frame counter.
        let f1 = store.stats().frames;
        let mut scratch = Vec::new();
        t.put_interned(&mut scratch, "__relexi:ctl:hb:w0", Value::Scalar(1.0)).unwrap();
        t.put_interned(&mut scratch, "__relexi:ctl:hb:w0", Value::Scalar(2.0)).unwrap();
        assert_eq!(store.stats().frames, f1, "ctl puts never count as data frames");
        assert_eq!(store.get("__relexi:ctl:hb:w0").unwrap().as_scalar(), Some(2.0));

        // Batched subscription drain (first hit blocks, rest drain).
        let mut sub2 = t.subscribe().unwrap();
        sub2.add(0, "sm:a").unwrap();
        sub2.add(1, "sm:b").unwrap();
        store.put("sm:a", Value::Scalar(1.0));
        store.put("sm:b", Value::Scalar(2.0));
        let mut got = sub2.wait_take_many(Duration::from_secs(5), 8).unwrap();
        while got.len() < 2 {
            got.extend(sub2.wait_take_many(Duration::from_secs(5), 8).unwrap());
        }
        let mut tags: Vec<usize> = got.iter().map(|(t, _)| *t).collect();
        tags.sort_unstable();
        assert_eq!(tags, vec![0, 1]);

        t.put("c", Value::Scalar(0.0)).unwrap();
        t.clear().unwrap();
        assert!(store.is_empty());
        drop(server);
    }

    #[cfg(unix)]
    #[test]
    fn shm_transport_round_trips_tensors() {
        let store = Arc::new(ShardedStore::new(4));
        let server = ExchangeServer::bind(store.clone(), "127.0.0.1:0").unwrap();
        let t = RemoteTransport::connect("shm", &server.addr().to_string(), 1).unwrap();
        assert_eq!(t.kind(), "shm");

        let data: Vec<f32> = (0..10_000).map(|i| i as f32 * 0.5).collect();
        t.put("big", Value::tensor(vec![10_000], data.clone())).unwrap();
        let (shape, got) = store.get("big").unwrap().as_tensor().map(|(s, d)| (s.to_vec(), d.to_vec())).unwrap();
        assert_eq!(shape, vec![10_000]);
        assert_eq!(got, data, "f32 payload crosses the rings bit-exactly");

        let back = t.take("big").unwrap().unwrap();
        assert_eq!(back.as_tensor().unwrap().1, &data[..]);

        // A frame larger than the ring streams through in chunks.
        let huge: Vec<f32> = vec![1.25; (SHM_RING_BYTES / 4) + 1000];
        t.put("huge", Value::tensor(vec![huge.len()], huge.clone())).unwrap();
        assert_eq!(
            t.get("huge").unwrap().unwrap().as_tensor().unwrap().1,
            &huge[..]
        );
        drop(server);
    }

    #[test]
    fn dial_failure_reports_attempts_and_elapsed() {
        // Bind a port, then drop the listener: nothing answers there.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = RemoteTransport::connect("tcp", &addr, 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("dial tcp exchange"), "{msg}");
        assert!(msg.contains("failed after 2 attempt(s)"), "{msg}");
        assert!(msg.contains("last error"), "{msg}");
    }

    #[test]
    fn injected_frame_drop_forces_redial_and_the_op_still_succeeds() {
        let store = Arc::new(ShardedStore::new(2));
        let server = ExchangeServer::bind(store.clone(), "127.0.0.1:0").unwrap();
        // Frame 2's first attempt fails synthetically; frame 3 is
        // delayed.  Both ops must still land.
        let fault =
            TransportFault::new(None, vec![2], vec![(3, Duration::from_millis(10))]);
        let t = RemoteTransport::connect_with_fault(
            "tcp",
            &server.addr().to_string(),
            1,
            fault,
        )
        .unwrap();
        t.put("a", Value::Scalar(1.0)).unwrap();
        t.put("b", Value::Scalar(2.0)).unwrap(); // dropped once, retried fresh
        t.put("c", Value::Scalar(3.0)).unwrap(); // delayed, then clean
        assert_eq!(store.get("a").unwrap().as_scalar(), Some(1.0));
        assert_eq!(store.get("b").unwrap().as_scalar(), Some(2.0));
        assert_eq!(store.get("c").unwrap().as_scalar(), Some(3.0));
        drop(server);
    }

    #[test]
    fn server_rejects_garbage_frames_without_dying() {
        let store = Arc::new(ShardedStore::new(1));
        let server = ExchangeServer::bind(store.clone(), "127.0.0.1:0").unwrap();

        // A raw client sending a malformed frame gets an error reply.
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(&3u32.to_le_bytes()).unwrap();
        s.write_all(&[200, 1, 2]).unwrap(); // unknown opcode
        let mut tcp = TcpConn::new(s).unwrap();
        let mut buf = Vec::new();
        assert!(tcp.recv(&mut buf, Duration::from_secs(5)).unwrap());
        match Response::decode(&buf).unwrap() {
            Response::Error(m) => assert!(m.contains("bad request frame"), "{m}"),
            other => panic!("{other:?}"),
        }

        // The server survives: a fresh well-formed client still works.
        let t = RemoteTransport::connect("tcp", &server.addr().to_string(), 1).unwrap();
        t.put("ok", Value::Flag(true)).unwrap();
        assert_eq!(store.get("ok").unwrap().as_flag(), Some(true));
    }
}
