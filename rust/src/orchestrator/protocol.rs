//! Key protocol between the coordinator and the environment workers —
//! the Relexi <-> FLEXI dataflow of paper §3.1/§3.3:
//!
//! * env writes   `e{env}:s{step}:state`  (obs tensor)  + `e{env}:done`
//! * trainer writes `e{env}:s{step}:action`
//! * env reads the action, advances `dt_RL`, writes the shaped reward
//!   scalar (`:rew`, computed env-side so the collector stays
//!   backend-agnostic) and the next state
//!
//! Step indices in the keys prevent stale reads without needing message
//! queues, mirroring how Relexi names tensors in the SmartSim database.
//! The run tag namespaces one sampling phase; persistent workers receive
//! a fresh `Protocol` in each iteration's begin message, so one worker
//! thread serves many iterations without key collisions.
//!
//! The trainer may consume these keys either lock-step (one blocking poll
//! per env, the paper's synchronous baseline) or event-driven through
//! [`crate::orchestrator::Client::poll_any_take`], in whichever order envs
//! finish — the key names are identical in both modes.
//!
//! For the steady-state rollout loop both sides intern their keys once
//! per iteration ([`Protocol::env_keys`] worker-side,
//! [`Protocol::pool_keys`] trainer-side): the per-step exchange then does
//! no `format!` string building and no rehashing — every operation uses a
//! precomputed [`Key`] handle.

use super::store::Key;
use super::value::wire;
use anyhow::Result;

/// Control-plane key namespace for process-level env workers (the
/// `workers = "processes"` mode): the worker lifecycle rides the same
/// store/transport as the data plane, so there is no second channel to
/// keep ordered.  The `__relexi:` prefix keeps these keys clear of any
/// run tag, and they are written outside the collect window, so the
/// trainer's between-iteration `clear()` cannot race them.
///
/// * `ctl_hello_key(w)`   — flag put by worker `w` once its env threads
///   are up; the pool's process spawn blocks on it.
/// * `ctl_begin_key(w)`   — bytes payload ([`encode_begin`]) assigning
///   worker `w` one iteration's run tag + per-env RNG seeds.  Consumed
///   (deleted) by the worker.
/// * `ctl_hb_key(w)`      — scalar heartbeat counter worker `w` bumps on
///   a configurable cadence (`orchestrator.heartbeat_period_ms`); the
///   supervision layer declares the worker wedged when the counter stops
///   advancing for `heartbeat_expiry_ms`.
/// * [`CTL_STOP_KEY`]     — flag read non-destructively by every worker;
///   set once at pool teardown.
pub fn ctl_begin_key(worker: usize) -> String {
    format!("__relexi:ctl:w{worker}:begin")
}

/// See [`ctl_begin_key`].
pub fn ctl_hello_key(worker: usize) -> String {
    format!("__relexi:ctl:w{worker}:hello")
}

/// Liveness heartbeat key for worker `w` (see [`ctl_begin_key`] docs).
pub fn ctl_hb_key(worker: usize) -> String {
    format!("__relexi:ctl:hb:w{worker}")
}

/// Shared stop flag for all env-worker processes (see [`ctl_begin_key`]).
pub const CTL_STOP_KEY: &str = "__relexi:ctl:stop";

/// Telemetry blob key for worker `w`: the worker serializes its span rings
/// and histograms (`util::telemetry::serialize_process`) and puts them here
/// when the trainer bumps [`CTL_TEL_FLUSH_KEY`]; the trainer takes the blob
/// and merges it into the run-wide trace.  Ctl-prefixed, so exempt from the
/// `frames`/`batched_keys` wave accounting like every other control key.
pub fn ctl_tel_key(worker: usize) -> String {
    format!("__relexi:ctl:tel:w{worker}")
}

/// Telemetry flush signal: a scalar the trainer bumps after each
/// iteration's `clear()`; workers read it non-destructively (like
/// [`CTL_STOP_KEY`]) and ship their buffers when the value advances.
pub const CTL_TEL_FLUSH_KEY: &str = "__relexi:ctl:tel:flush";

/// Encode one iteration's begin message for a worker process: the run
/// tag plus `(global env index, rng seed)` per hosted env.  The seed is
/// [`crate::util::rng::Rng::split_seed`] output, so the worker rebuilds
/// the exact RNG stream the threads mode would have handed it.
pub fn encode_begin(run_tag: &str, envs: &[(usize, u64)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + run_tag.len() + envs.len() * 12);
    wire::w_str(&mut out, run_tag);
    wire::w_u32(&mut out, envs.len() as u32);
    for &(env, seed) in envs {
        wire::w_u32(&mut out, env as u32);
        wire::w_u64(&mut out, seed);
    }
    out
}

/// Decode [`encode_begin`] output; malformed bytes are an `Err`.
pub fn decode_begin(buf: &[u8]) -> Result<(String, Vec<(usize, u64)>)> {
    let mut pos = 0;
    let tag = wire::r_str(buf, &mut pos)?;
    let n = wire::r_u32(buf, &mut pos)? as usize;
    anyhow::ensure!(n <= 1 << 20, "begin message claims {n} envs");
    let mut envs = Vec::with_capacity(n);
    for _ in 0..n {
        let env = wire::r_u32(buf, &mut pos)? as usize;
        let seed = wire::r_u64(buf, &mut pos)?;
        envs.push((env, seed));
    }
    anyhow::ensure!(pos == buf.len(), "trailing bytes after begin message");
    Ok((tag, envs))
}

/// Key builder for one training run.
#[derive(Debug, Clone)]
pub struct Protocol {
    run_tag: String,
}

impl Protocol {
    /// Namespacing tag keeps concurrent runs apart in one store.
    pub fn new(run_tag: &str) -> Protocol {
        Protocol {
            run_tag: run_tag.to_string(),
        }
    }

    /// The namespacing tag this protocol was built with.
    pub fn run_tag(&self) -> &str {
        &self.run_tag
    }

    /// State tensor written by env `env` after RL step `step`.
    pub fn state_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:state", self.run_tag, env, step)
    }

    /// Action tensor for env `env` at RL step `step`.
    pub fn action_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:action", self.run_tag, env, step)
    }

    /// Shaped reward scalar accompanying a state.  Computed by the env
    /// worker (each backend owns its reward shaping), so the trainer
    /// side never needs backend-specific reward knowledge.
    pub fn reward_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:rew", self.run_tag, env, step)
    }

    /// Terminal flag for env `env` ("will terminate", §3.1).
    pub fn done_key(&self, env: usize) -> String {
        format!("{}:e{}:done", self.run_tag, env)
    }

    /// Failure report from env `env` (worker error message as bytes).
    /// Subscribed to by the collector so a failing worker aborts the
    /// iteration immediately instead of timing out a blocking poll.
    pub fn fail_key(&self, env: usize) -> String {
        format!("{}:e{}:fail", self.run_tag, env)
    }

    /// Run-wide abort flag: workers subscribe to it alongside their
    /// action key, so a pool teardown mid-iteration unblocks them
    /// immediately instead of running out the poll timeout.
    pub fn abort_key(&self) -> String {
        format!("{}:abort", self.run_tag)
    }

    /// Intern every key one env worker touches in one iteration
    /// (`n_actions` RL steps).  Built once per begin message; the
    /// per-step loop then only passes precomputed handles.
    pub fn env_keys(&self, env: usize, n_actions: usize) -> EnvKeys {
        EnvKeys {
            // One state slot past the horizon: the collector waits on the
            // never-written post-terminal index until the done-flag
            // resolves that wait.
            state: (0..=n_actions)
                .map(|t| Key::new(self.state_key(env, t)))
                .collect(),
            action: (0..n_actions)
                .map(|t| Key::new(self.action_key(env, t)))
                .collect(),
            rew: (0..n_actions)
                .map(|t| Key::new(self.reward_key(env, t)))
                .collect(),
            done: Key::new(self.done_key(env)),
            fail: Key::new(self.fail_key(env)),
            abort: Key::new(self.abort_key()),
        }
    }

    /// Intern the whole pool's key set trainer-side (`n_actions_of[i]` =
    /// horizon of env `i`; heterogeneous pools have per-variant horizons).
    pub fn pool_keys(&self, n_actions_of: &[usize]) -> PoolKeys {
        PoolKeys {
            envs: n_actions_of
                .iter()
                .enumerate()
                .map(|(i, &n)| self.env_keys(i, n))
                .collect(),
        }
    }
}

/// Interned handles for every key one env touches in one iteration (see
/// [`Protocol::env_keys`]).
#[derive(Debug, Clone)]
pub struct EnvKeys {
    /// `state[t]`, `t` up to and including the never-written
    /// post-terminal index (the done-flag resolves that wait).
    pub state: Vec<Key>,
    pub action: Vec<Key>,
    pub rew: Vec<Key>,
    pub done: Key,
    pub fail: Key,
    pub abort: Key,
}

/// Trainer-side interned key set for the whole pool (see
/// [`Protocol::pool_keys`]).
#[derive(Debug, Clone)]
pub struct PoolKeys {
    /// Indexed by env.
    pub envs: Vec<EnvKeys>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_distinct_and_stable() {
        let p = Protocol::new("it3");
        assert_eq!(p.state_key(0, 0), "it3:e0:s0:state");
        assert_ne!(p.state_key(1, 0), p.state_key(0, 0));
        assert_ne!(p.state_key(0, 1), p.state_key(0, 0));
        assert_ne!(p.action_key(0, 0), p.state_key(0, 0));
        assert_ne!(p.reward_key(0, 0), p.state_key(0, 0));
        assert_ne!(p.fail_key(0), p.done_key(0));
        assert_eq!(p.run_tag(), "it3");
    }

    #[test]
    fn runs_are_namespaced() {
        let a = Protocol::new("runA");
        let b = Protocol::new("runB");
        assert_ne!(a.state_key(0, 0), b.state_key(0, 0));
    }

    #[test]
    fn interned_keys_match_the_string_builders() {
        let p = Protocol::new("it7");
        let ek = p.env_keys(2, 3);
        assert_eq!(ek.state.len(), 4, "one post-terminal state slot");
        assert_eq!(ek.action.len(), 3);
        assert_eq!(ek.rew.len(), 3);
        for t in 0..3 {
            assert_eq!(ek.state[t].name(), p.state_key(2, t));
            assert_eq!(ek.action[t].name(), p.action_key(2, t));
            assert_eq!(ek.rew[t].name(), p.reward_key(2, t));
        }
        assert_eq!(ek.state[3].name(), p.state_key(2, 3));
        assert_eq!(ek.done.name(), p.done_key(2));
        assert_eq!(ek.fail.name(), p.fail_key(2));
        assert_eq!(ek.abort.name(), p.abort_key());

        let pk = p.pool_keys(&[3, 1]);
        assert_eq!(pk.envs.len(), 2);
        assert_eq!(pk.envs[1].state.len(), 2);
        assert_eq!(pk.envs[1].action[0].name(), p.action_key(1, 0));
    }

    #[test]
    fn ctl_keys_are_distinct_and_outside_run_namespaces() {
        assert_ne!(ctl_begin_key(0), ctl_begin_key(1));
        assert_ne!(ctl_begin_key(0), ctl_hello_key(0));
        assert_ne!(ctl_hb_key(0), ctl_hb_key(1));
        assert_ne!(ctl_hb_key(0), ctl_hello_key(0));
        assert!(ctl_begin_key(3).starts_with("__relexi:ctl:"));
        assert!(ctl_hb_key(3).starts_with("__relexi:ctl:hb:"));
        assert!(CTL_STOP_KEY.starts_with("__relexi:ctl:"));
        assert_ne!(ctl_tel_key(0), ctl_tel_key(1));
        assert_ne!(ctl_tel_key(0), CTL_TEL_FLUSH_KEY);
        assert!(ctl_tel_key(2).starts_with("__relexi:ctl:tel:"));
        assert!(CTL_TEL_FLUSH_KEY.starts_with("__relexi:ctl:tel:"));
    }

    #[test]
    fn begin_message_round_trips_and_rejects_garbage() {
        let envs = vec![(0usize, 7u64), (5, u64::MAX), (1 << 20, 0)];
        let buf = encode_begin("it42", &envs);
        let (tag, back) = decode_begin(&buf).unwrap();
        assert_eq!(tag, "it42");
        assert_eq!(back, envs);

        for cut in 0..buf.len() {
            assert!(decode_begin(&buf[..cut]).is_err(), "cut at {cut}");
        }
        let mut trailing = buf.clone();
        trailing.push(0);
        assert!(decode_begin(&trailing).is_err());
    }
}
