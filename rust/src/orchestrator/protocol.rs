//! Key protocol between the coordinator and the environment workers —
//! the Relexi <-> FLEXI dataflow of paper §3.1/§3.3:
//!
//! * env writes   `e{env}:s{step}:state`  (obs tensor)  + `e{env}:done`
//! * trainer writes `e{env}:s{step}:action`
//! * env reads the action, advances `dt_RL`, writes the next state
//!
//! Step indices in the keys prevent stale reads without needing message
//! queues, mirroring how Relexi names tensors in the SmartSim database.
//! The run tag namespaces one sampling phase; persistent workers receive
//! a fresh `Protocol` in each iteration's begin message, so one worker
//! thread serves many iterations without key collisions.
//!
//! The trainer may consume these keys either lock-step (one blocking poll
//! per env, the paper's synchronous baseline) or event-driven through
//! [`crate::orchestrator::Client::poll_any_take`], in whichever order envs
//! finish — the key names are identical in both modes.

/// Key builder for one training run.
#[derive(Debug, Clone)]
pub struct Protocol {
    run_tag: String,
}

impl Protocol {
    /// Namespacing tag keeps concurrent runs apart in one store.
    pub fn new(run_tag: &str) -> Protocol {
        Protocol {
            run_tag: run_tag.to_string(),
        }
    }

    /// The namespacing tag this protocol was built with.
    pub fn run_tag(&self) -> &str {
        &self.run_tag
    }

    /// State tensor written by env `env` after RL step `step`.
    pub fn state_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:state", self.run_tag, env, step)
    }

    /// Action tensor for env `env` at RL step `step`.
    pub fn action_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:action", self.run_tag, env, step)
    }

    /// Spectrum-error scalar accompanying a state (reward input).
    pub fn error_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:err", self.run_tag, env, step)
    }

    /// Terminal flag for env `env` ("will terminate", §3.1).
    pub fn done_key(&self, env: usize) -> String {
        format!("{}:e{}:done", self.run_tag, env)
    }

    /// Failure report from env `env` (worker error message as bytes).
    /// Subscribed to by the collector so a failing worker aborts the
    /// iteration immediately instead of timing out a blocking poll.
    pub fn fail_key(&self, env: usize) -> String {
        format!("{}:e{}:fail", self.run_tag, env)
    }

    /// Run-wide abort flag: workers subscribe to it alongside their
    /// action key, so a pool teardown mid-iteration unblocks them
    /// immediately instead of running out the poll timeout.
    pub fn abort_key(&self) -> String {
        format!("{}:abort", self.run_tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_distinct_and_stable() {
        let p = Protocol::new("it3");
        assert_eq!(p.state_key(0, 0), "it3:e0:s0:state");
        assert_ne!(p.state_key(1, 0), p.state_key(0, 0));
        assert_ne!(p.state_key(0, 1), p.state_key(0, 0));
        assert_ne!(p.action_key(0, 0), p.state_key(0, 0));
        assert_ne!(p.error_key(0, 0), p.state_key(0, 0));
        assert_ne!(p.fail_key(0), p.done_key(0));
        assert_eq!(p.run_tag(), "it3");
    }

    #[test]
    fn runs_are_namespaced() {
        let a = Protocol::new("runA");
        let b = Protocol::new("runB");
        assert_ne!(a.state_key(0, 0), b.state_key(0, 0));
    }
}
