//! Key protocol between the coordinator and the environment workers —
//! the Relexi <-> FLEXI dataflow of paper §3.1/§3.3:
//!
//! * env writes   `e{env}:s{step}:state`  (obs tensor)  + `e{env}:done`
//! * trainer writes `e{env}:s{step}:action`
//! * env reads the action, advances `dt_RL`, writes the next state
//!
//! Step indices in the keys prevent stale reads without needing message
//! queues, mirroring how Relexi names tensors in the SmartSim database.

/// Key builder for one training run.
#[derive(Debug, Clone)]
pub struct Protocol {
    run_tag: String,
}

impl Protocol {
    /// Namespacing tag keeps concurrent runs apart in one store.
    pub fn new(run_tag: &str) -> Protocol {
        Protocol {
            run_tag: run_tag.to_string(),
        }
    }

    /// State tensor written by env `env` after RL step `step`.
    pub fn state_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:state", self.run_tag, env, step)
    }

    /// Action tensor for env `env` at RL step `step`.
    pub fn action_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:action", self.run_tag, env, step)
    }

    /// Spectrum-error scalar accompanying a state (reward input).
    pub fn error_key(&self, env: usize, step: usize) -> String {
        format!("{}:e{}:s{}:err", self.run_tag, env, step)
    }

    /// Terminal flag for env `env` ("will terminate", §3.1).
    pub fn done_key(&self, env: usize) -> String {
        format!("{}:e{}:done", self.run_tag, env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_distinct_and_stable() {
        let p = Protocol::new("it3");
        assert_eq!(p.state_key(0, 0), "it3:e0:s0:state");
        assert_ne!(p.state_key(1, 0), p.state_key(0, 0));
        assert_ne!(p.state_key(0, 1), p.state_key(0, 0));
        assert_ne!(p.action_key(0, 0), p.state_key(0, 0));
        assert_ne!(p.error_key(0, 0), p.state_key(0, 0));
    }

    #[test]
    fn runs_are_namespaced() {
        let a = Protocol::new("runA");
        let b = Protocol::new("runB");
        assert_ne!(a.state_key(0, 0), b.state_key(0, 0));
    }
}
