//! The runtime-backend seam: [`Policy`] / [`Trainer`] trait objects and
//! the `runtime.backend` registry that makes the XLA-artifact path and
//! the native in-process path interchangeable.
//!
//! The contract both backends satisfy:
//!
//! * **Parameters are a flat f32 vector owned by the trainer.**  The
//!   policy is stateless with respect to parameters: every
//!   [`Policy::forward`] receives the current `theta` explicitly (the
//!   training loop passes `trainer.theta()`), so checkpointing is one
//!   `write_f32_vec` regardless of backend.
//! * **`forward` is deterministic** — same `theta` + `obs` gives
//!   bitwise-identical outputs — and returns one `(mean, value)` pair
//!   per sample plus one global finite `log_std`, with `mean` inside
//!   the admissible `[0, 0.5]` Cs range (`tests/conformance_policy.rs`
//!   asserts this against every registered backend).
//! * **`train_minibatch` is one optimizer step** of the clipped-PPO
//!   objective on exactly [`Trainer::minibatch`] samples, returning the
//!   paper-standard [`TrainMetrics`] diagnostics; `set_theta` restores a
//!   checkpoint and resets the optimizer state.
//!
//! Where they differ: the XLA path loads pre-compiled `policy_fwd` /
//! `train_step` HLO modules from `paths.artifacts` (shapes fixed at
//! lowering time — today's artifacts are LES-shaped), while the native
//! path sizes its input layer from the environment pool at construction
//! and therefore trains **any** registered CFD backend with zero
//! artifacts on disk.

use super::native::{NativePolicy, NativeSpec, NativeTrainer};
use super::policy::{PolicyOut, PolicyRuntime};
use super::trainer::{Minibatch, TrainMetrics, TrainerRuntime};
use super::{Registry, Runtime};
use crate::config::RunConfig;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Batched policy evaluation behind the rollout stack (see the module
/// docs for the exact contract).
pub trait Policy: Send + Sync {
    /// Observation floats per sample this policy is shaped for.
    fn features(&self) -> usize;

    /// Evaluate `n_samples` observations (`obs.len() == n_samples *
    /// features()`) under the flat parameter vector `theta`.
    fn forward(&self, theta: &[f32], obs: &[f32], n_samples: usize) -> Result<PolicyOut>;
}

/// Owner of the flat parameter vector + optimizer state (see the module
/// docs for the exact contract).
pub trait Trainer: Send {
    /// Samples per PPO minibatch.
    fn minibatch(&self) -> usize;

    /// Current flat parameters.
    fn theta(&self) -> &[f32];

    /// Optimizer step counter.
    fn opt_step(&self) -> f32;

    /// Restore parameters (checkpoint load); resets optimizer state.
    /// Fails when the vector length does not match this architecture.
    fn set_theta(&mut self, theta: Vec<f32>) -> Result<()>;

    /// Apply one PPO + optimizer step on one minibatch.
    fn train_minibatch(&mut self, mb: &Minibatch) -> Result<TrainMetrics>;
}

impl Policy for PolicyRuntime {
    fn features(&self) -> usize {
        PolicyRuntime::features(self)
    }

    fn forward(&self, theta: &[f32], obs: &[f32], n_samples: usize) -> Result<PolicyOut> {
        PolicyRuntime::forward(self, theta, obs, n_samples)
    }
}

impl Trainer for TrainerRuntime {
    fn minibatch(&self) -> usize {
        self.minibatch
    }

    fn theta(&self) -> &[f32] {
        TrainerRuntime::theta(self)
    }

    fn opt_step(&self) -> f32 {
        TrainerRuntime::opt_step(self)
    }

    fn set_theta(&mut self, theta: Vec<f32>) -> Result<()> {
        TrainerRuntime::set_theta(self, theta)
    }

    fn train_minibatch(&mut self, mb: &Minibatch) -> Result<TrainMetrics> {
        TrainerRuntime::train_minibatch(self, mb)
    }
}

impl Policy for NativePolicy {
    fn features(&self) -> usize {
        NativePolicy::features(self)
    }

    fn forward(&self, theta: &[f32], obs: &[f32], n_samples: usize) -> Result<PolicyOut> {
        NativePolicy::forward(self, theta, obs, n_samples)
    }
}

impl Trainer for NativeTrainer {
    fn minibatch(&self) -> usize {
        self.spec().minibatch
    }

    fn theta(&self) -> &[f32] {
        NativeTrainer::theta(self)
    }

    fn opt_step(&self) -> f32 {
        NativeTrainer::opt_step(self)
    }

    fn set_theta(&mut self, theta: Vec<f32>) -> Result<()> {
        NativeTrainer::set_theta(self, theta)
    }

    fn train_minibatch(&mut self, mb: &Minibatch) -> Result<TrainMetrics> {
        NativeTrainer::train_minibatch(self, mb)
    }
}

/// Resolve `runtime.backend` to a matched (policy, trainer) pair.
///
/// `features` is the environment pool's per-agent observation width:
/// the native backend sizes its input layer from it, the XLA backend
/// ignores it (artifact shapes were fixed at lowering time; the caller
/// checks `policy.features()` against the pool afterwards).
pub fn runtime_from_config(
    cfg: &RunConfig,
    features: usize,
) -> Result<(Box<dyn Policy>, Box<dyn Trainer>)> {
    match cfg.runtime.backend.as_str() {
        "xla" => {
            let rt = Runtime::cpu()?;
            let reg = Registry::open(Path::new(&cfg.artifacts_dir))
                .context("open artifact registry")?;
            let policy = PolicyRuntime::load(&rt, &reg, cfg.case.n)?;
            let trainer = TrainerRuntime::load(&rt, &reg, cfg.case.n, cfg.rl.minibatch)?;
            Ok((Box::new(policy), Box::new(trainer)))
        }
        "native" => {
            let spec = NativeSpec::from_config(cfg, features)?;
            let policy = NativePolicy::new(spec.clone());
            let trainer = NativeTrainer::new(spec);
            Ok((Box::new(policy), Box::new(trainer)))
        }
        other => bail!(
            "unknown runtime.backend {other:?} (expected one of {:?})",
            crate::config::RUNTIME_BACKENDS
        ),
    }
}
