//! `runtime::native` — the in-process policy/trainer subsystem.
//!
//! The XLA path executes pre-compiled `policy_fwd`/`train_step` artifacts
//! that no CI container can build; this module is the artifact-free twin:
//! a pure-Rust MLP policy (tanh hidden layers, a Gaussian-mean head
//! bounded to `[0, 0.5]` by a scaled sigmoid, a linear state-value head,
//! one global learnable `log_std`), hand-written reverse-mode backprop,
//! the full clipped-PPO surrogate loss producing the same
//! [`TrainMetrics`] diagnostics as the compiled train step, and an Adam
//! optimizer over a single flat `theta` vector.  Because the parameters
//! are one flat f32 vector, the existing `save_checkpoint` /
//! `load_checkpoint` binio format works unchanged.
//!
//! Contract with the rollout stack (shared with the XLA path through the
//! [`super::Policy`] / [`super::Trainer`] traits):
//!
//! * `forward(theta, obs, n)` consumes `n * features` floats and returns
//!   one `(mean, value)` pair per sample plus the global `log_std`;
//!   `mean` stays inside `[0, 0.5]` (the admissible Cs range) for any
//!   input.  Forward is deterministic: same `theta` + `obs` give
//!   bitwise-identical outputs.
//! * The input layer is sized at construction from the environment
//!   pool's `features()` — the native runtime adapts to ANY registered
//!   CFD backend, which is what makes `relexi train` work end-to-end
//!   with zero artifacts on disk.
//! * `train_minibatch` applies exactly one Adam step of the clipped-PPO
//!   objective (`pg + vf_coef * value - ent_coef * entropy`, paper §5.3)
//!   and reports loss/pg/vf/entropy/clip-fraction/approx-KL, mirroring
//!   the compiled artifact's 10-output tuple.
//!
//! Parameter layout (flat `theta`):
//! `[W_0, b_0, …, W_{L-1}, b_{L-1}, w_mean, b_mean, w_value, b_value,
//! log_std]` with `W_l` row-major `(d_l × d_{l+1})`.  The layout is a
//! pure function of `(features, hidden)`, so checkpoints are portable
//! across runs with the same architecture and rejected (length check)
//! otherwise.
//!
//! All linear algebra runs through the cache-blocked kernels in
//! [`gemm`]; per-sample loss scalars are accumulated in f64 (matching
//! the f64 math of [`crate::rl::gaussian`] on the sampling side) while
//! tensors stay f32.

pub mod gemm;

use super::trainer::{Minibatch, TrainMetrics};
use super::PolicyOut;
use crate::config::RunConfig;
use crate::rl::gaussian::HALF_LN_2PI;
use crate::util::Rng;
use anyhow::Result;
use std::sync::Mutex;

/// Adam moments (paper §5.3 hyperparameters, fixed at lowering time on
/// the XLA path; fixed here for parity).
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;

/// Tag xored into `rl.seed` for the parameter-init stream, so weight
/// init never aliases the env/action sampling streams.
const INIT_SEED_TAG: u64 = 0x6e61_7469_7665_3031; // "native01"

/// Architecture + hyperparameters of the native subsystem, resolved from
/// the `[runtime]` config section and the environment pool's feature
/// count.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    /// Observation floats per agent (the input-layer width) — taken from
    /// `EnvPool::features()` so the policy fits whatever backend runs.
    pub features: usize,
    /// Hidden-layer widths (tanh activations); must be non-empty.
    pub hidden: Vec<usize>,
    /// Samples per PPO minibatch (`rl.minibatch`).
    pub minibatch: usize,
    /// Adam learning rate.
    pub lr: f64,
    /// PPO clipping radius epsilon.
    pub clip_eps: f64,
    /// Value-loss coefficient.
    pub vf_coef: f64,
    /// Entropy-bonus coefficient.
    pub ent_coef: f64,
    /// Initial global log standard deviation.
    pub log_std_init: f64,
    /// Weight-init RNG seed.
    pub seed: u64,
}

impl NativeSpec {
    /// Resolve the spec from a run configuration and the pool's feature
    /// count (the construction-time shape handshake the XLA path can
    /// only check after the fact).
    pub fn from_config(cfg: &RunConfig, features: usize) -> Result<NativeSpec> {
        let r = &cfg.runtime;
        anyhow::ensure!(features >= 1, "native policy needs at least one input feature");
        // Section checks live on RuntimeConfig (one source of truth with
        // RunConfig::validate); re-run them here for callers that build
        // a spec without going through a validated full config.
        r.validate()?;
        Ok(NativeSpec {
            features,
            hidden: r.hidden.clone(),
            minibatch: cfg.rl.minibatch,
            lr: r.lr,
            clip_eps: r.clip_eps,
            vf_coef: r.vf_coef,
            ent_coef: r.ent_coef,
            log_std_init: r.log_std_init,
            seed: cfg.rl.seed ^ INIT_SEED_TAG,
        })
    }

    /// Total flat-parameter count of this architecture.
    pub fn param_count(&self) -> usize {
        Layout::new(self.features, &self.hidden).total
    }

    /// Deterministic initial parameter vector: Xavier-scaled normal
    /// trunk weights (`std = 1/sqrt(fan_in)`, the tanh-appropriate
    /// scale), small head weights (`std = 0.1/sqrt(d_last)`) so the
    /// initial mean sits near the center of the admissible Cs range
    /// (`0.5 * sigmoid(~0) = 0.25`) and the initial value near zero,
    /// zero biases, and `log_std_init`.
    pub fn init_theta(&self) -> Vec<f32> {
        let layout = Layout::new(self.features, &self.hidden);
        let mut rng = Rng::new(self.seed);
        let mut theta = vec![0f32; layout.total];
        for (l, &(w_off, _b_off)) in layout.layers.iter().enumerate() {
            let (din, dout) = (layout.dims[l], layout.dims[l + 1]);
            let std = (1.0 / din as f64).sqrt();
            for w in theta[w_off..w_off + din * dout].iter_mut() {
                *w = (rng.normal() * std) as f32;
            }
            // Biases stay zero.
        }
        let dm = *layout.dims.last().expect("layout has at least the input dim");
        let head_std = 0.1 / (dm as f64).sqrt();
        for w in theta[layout.mean_w..layout.mean_w + dm].iter_mut() {
            *w = (rng.normal() * head_std) as f32;
        }
        for w in theta[layout.value_w..layout.value_w + dm].iter_mut() {
            *w = (rng.normal() * head_std) as f32;
        }
        theta[layout.log_std] = self.log_std_init as f32;
        theta
    }
}

/// Offsets of every parameter block inside the flat `theta` vector.
#[derive(Debug, Clone)]
pub(crate) struct Layout {
    /// Widths of the trunk: `[features, hidden[0], …, hidden[L-1]]`.
    pub dims: Vec<usize>,
    /// `(w_offset, b_offset)` per trunk layer; `W_l` is row-major
    /// `dims[l] × dims[l+1]`.
    pub layers: Vec<(usize, usize)>,
    pub mean_w: usize,
    pub mean_b: usize,
    pub value_w: usize,
    pub value_b: usize,
    pub log_std: usize,
    pub total: usize,
}

impl Layout {
    pub fn new(features: usize, hidden: &[usize]) -> Layout {
        let mut dims = Vec::with_capacity(hidden.len() + 1);
        dims.push(features);
        dims.extend_from_slice(hidden);
        let mut layers = Vec::with_capacity(hidden.len());
        let mut off = 0usize;
        for l in 0..dims.len() - 1 {
            let w_off = off;
            off += dims[l] * dims[l + 1];
            let b_off = off;
            off += dims[l + 1];
            layers.push((w_off, b_off));
        }
        let dm = *dims.last().expect("dims is never empty");
        let mean_w = off;
        let mean_b = mean_w + dm;
        let value_w = mean_b + 1;
        let value_b = value_w + dm;
        let log_std = value_b + 1;
        Layout {
            dims,
            layers,
            mean_w,
            mean_b,
            value_w,
            value_b,
            log_std,
            total: log_std + 1,
        }
    }
}

/// Reused forward scratch: per-layer post-tanh activations and the
/// sigmoid of the mean-head logit (cached for backprop).
#[derive(Default)]
struct Scratch {
    acts: Vec<Vec<f32>>,
    sig: Vec<f32>,
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Forward pass: trunk activations into `sc.acts`, head outputs into
/// `mean`/`value` (cleared first), `sigmoid(z_mean)` into `sc.sig`.
fn forward(
    layout: &Layout,
    theta: &[f32],
    obs: &[f32],
    batch: usize,
    sc: &mut Scratch,
    mean: &mut Vec<f32>,
    value: &mut Vec<f32>,
) {
    let nlayers = layout.layers.len();
    if sc.acts.len() != nlayers {
        sc.acts.resize_with(nlayers, Vec::new);
    }
    for l in 0..nlayers {
        let (din, dout) = (layout.dims[l], layout.dims[l + 1]);
        let (w_off, b_off) = layout.layers[l];
        let w = &theta[w_off..w_off + din * dout];
        let bias = &theta[b_off..b_off + dout];
        let (before, rest) = sc.acts.split_at_mut(l);
        let out = &mut rest[0];
        out.clear();
        out.reserve(batch * dout);
        for _ in 0..batch {
            out.extend_from_slice(bias);
        }
        let x: &[f32] = if l == 0 { obs } else { &before[l - 1] };
        gemm::gemm_nn(batch, din, dout, x, w, out);
        for v in out.iter_mut() {
            *v = v.tanh();
        }
    }
    let dm = *layout.dims.last().expect("dims is never empty");
    let act_last: &[f32] = sc.acts.last().expect("at least one hidden layer");
    let hw = &theta[layout.mean_w..layout.mean_w + dm];
    let vw = &theta[layout.value_w..layout.value_w + dm];
    let (hb, vb) = (theta[layout.mean_b], theta[layout.value_b]);
    sc.sig.clear();
    mean.clear();
    value.clear();
    for r in 0..batch {
        let h = &act_last[r * dm..(r + 1) * dm];
        let s = sigmoid(dot(h, hw) + hb);
        sc.sig.push(s);
        mean.push(0.5 * s);
        value.push(dot(h, vw) + vb);
    }
}

/// The native policy: a stateless-parameter forward pass over the flat
/// `theta` the trainer owns (the same calling convention as the compiled
/// `policy_fwd` artifacts, so both sit behind one [`super::Policy`]
/// trait object).
pub struct NativePolicy {
    spec: NativeSpec,
    layout: Layout,
    /// Forward scratch behind a mutex so `forward(&self, …)` stays
    /// shareable; contention-free in practice (one trainer thread).
    scratch: Mutex<Scratch>,
}

impl NativePolicy {
    /// Build a policy for the spec's architecture.
    pub fn new(spec: NativeSpec) -> NativePolicy {
        let layout = Layout::new(spec.features, &spec.hidden);
        NativePolicy {
            spec,
            layout,
            scratch: Mutex::new(Scratch::default()),
        }
    }

    /// Observation floats per sample.
    pub fn features(&self) -> usize {
        self.spec.features
    }

    /// Evaluate mean/value heads on `n_samples` observations.
    pub fn forward(&self, theta: &[f32], obs: &[f32], n_samples: usize) -> Result<PolicyOut> {
        let _sp = crate::span!("policy.forward");
        let _t = crate::util::telemetry::HistId::PolicyForward.timer();
        anyhow::ensure!(n_samples > 0, "empty forward batch");
        anyhow::ensure!(
            theta.len() == self.layout.total,
            "theta has {} params but the native {:?}-hidden architecture on {} features \
             needs {} — checkpoint from a different runtime.hidden / backend?",
            theta.len(),
            self.spec.hidden,
            self.spec.features,
            self.layout.total
        );
        anyhow::ensure!(
            obs.len() == n_samples * self.spec.features,
            "obs len {} != {n_samples} x {}",
            obs.len(),
            self.spec.features
        );
        let mut mean = Vec::with_capacity(n_samples);
        let mut value = Vec::with_capacity(n_samples);
        let mut sc = self.scratch.lock().expect("native policy scratch lock");
        forward(&self.layout, theta, obs, n_samples, &mut sc, &mut mean, &mut value);
        Ok(PolicyOut {
            mean,
            log_std: theta[self.layout.log_std],
            value,
        })
    }
}

/// The native trainer: owns `theta` and the Adam state, applies one
/// backprop + Adam step of the clipped-PPO objective per minibatch.
pub struct NativeTrainer {
    spec: NativeSpec,
    layout: Layout,
    theta: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: f64,
    /// Flat gradient, same layout as `theta` (reused across calls).
    grad: Vec<f32>,
    sc: Scratch,
    // Reused backward scratch.
    mean: Vec<f32>,
    value: Vec<f32>,
    dzm: Vec<f32>,
    dzv: Vec<f32>,
    dh: Vec<f32>,
    dh_prev: Vec<f32>,
    dz: Vec<f32>,
}

impl NativeTrainer {
    /// Fresh trainer with deterministic seed-derived initial parameters.
    pub fn new(spec: NativeSpec) -> NativeTrainer {
        let layout = Layout::new(spec.features, &spec.hidden);
        let theta = spec.init_theta();
        let n = theta.len();
        NativeTrainer {
            spec,
            layout,
            theta,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0.0,
            grad: vec![0.0; n],
            sc: Scratch::default(),
            mean: Vec::new(),
            value: Vec::new(),
            dzm: Vec::new(),
            dzv: Vec::new(),
            dh: Vec::new(),
            dh_prev: Vec::new(),
            dz: Vec::new(),
        }
    }

    /// The architecture/hyperparameter spec this trainer was built from.
    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }

    /// Current flat parameters (shared with the policy each forward).
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Optimizer step counter.
    pub fn opt_step(&self) -> f32 {
        self.step as f32
    }

    /// Restore parameters (checkpoint load); resets the Adam state, like
    /// the XLA trainer.
    pub fn set_theta(&mut self, theta: Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            theta.len() == self.layout.total,
            "checkpoint has {} params but the native {:?}-hidden architecture on {} \
             features needs {}",
            theta.len(),
            self.spec.hidden,
            self.spec.features,
            self.layout.total
        );
        self.theta = theta;
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0.0;
        Ok(())
    }

    /// One full PPO + Adam step on a minibatch of exactly
    /// `spec.minibatch` samples — same contract (and same failure mode
    /// on a short batch) as the XLA trainer, whose artifact shape is
    /// static.  [`NativeTrainer::loss_and_grad`] stays batch-size
    /// agnostic for gradient checks and diagnostics.
    pub fn train_minibatch(&mut self, mb: &Minibatch) -> Result<TrainMetrics> {
        let _sp = crate::span!("train.minibatch");
        let _t = crate::util::telemetry::HistId::TrainMinibatch.timer();
        anyhow::ensure!(
            mb.act.len() == self.spec.minibatch,
            "minibatch size {} != {}",
            mb.act.len(),
            self.spec.minibatch
        );
        let metrics = self.loss_and_grad(mb)?;
        self.adam_step();
        Ok(metrics)
    }

    /// The flat gradient left by the last [`NativeTrainer::loss_and_grad`]
    /// (layout identical to `theta`; exposed for the finite-difference
    /// gradient checks and the GEMM bench).
    pub fn grad(&self) -> &[f32] {
        &self.grad
    }

    /// Forward + clipped-PPO loss + reverse-mode backprop into
    /// [`NativeTrainer::grad`], without touching the parameters.
    pub fn loss_and_grad(&mut self, mb: &Minibatch) -> Result<TrainMetrics> {
        let b = mb.act.len();
        let feat = self.spec.features;
        anyhow::ensure!(b >= 1, "empty minibatch");
        anyhow::ensure!(
            mb.obs.len() == b * feat,
            "minibatch obs len {} != {b} x {feat}",
            mb.obs.len()
        );
        anyhow::ensure!(
            mb.old_logp.len() == b && mb.adv.len() == b && mb.ret.len() == b,
            "minibatch field lengths disagree with {b} actions"
        );

        // -- forward (caches activations + sigmoid for backprop) --------
        let layout = &self.layout;
        forward(
            layout,
            &self.theta,
            mb.obs,
            b,
            &mut self.sc,
            &mut self.mean,
            &mut self.value,
        );

        // -- loss + per-sample head gradients (f64 accumulators) --------
        self.grad.iter_mut().for_each(|g| *g = 0.0);
        let bn = b as f64;
        let eps = self.spec.clip_eps;
        let vf = self.spec.vf_coef;
        let ent_coef = self.spec.ent_coef;
        let ls = self.theta[layout.log_std] as f64;
        let sigma = ls.exp();
        let (mut pg_acc, mut v_acc, mut kl_acc, mut dls_acc) = (0.0f64, 0.0, 0.0, 0.0);
        let mut clipped = 0usize;
        self.dzm.clear();
        self.dzv.clear();
        for i in 0..b {
            let mu = self.mean[i] as f64;
            let z = (mb.act[i] as f64 - mu) / sigma;
            let logp = -0.5 * z * z - ls - HALF_LN_2PI;
            let ratio = (logp - mb.old_logp[i] as f64).exp();
            let adv = mb.adv[i] as f64;
            let unclipped = ratio * adv;
            let clamped = ratio.clamp(1.0 - eps, 1.0 + eps) * adv;
            pg_acc += -unclipped.min(clamped);
            if (ratio - 1.0).abs() > eps {
                clipped += 1;
            }
            kl_acc += mb.old_logp[i] as f64 - logp;
            // min() routes the gradient: the clamped branch only wins
            // when the ratio sits outside the clip interval, where the
            // clamp's derivative is zero — so either the unclipped
            // branch's gradient flows, or none does.
            let dratio = if unclipped <= clamped { -adv / bn } else { 0.0 };
            let dlogp = dratio * ratio;
            dls_acc += dlogp * (z * z - 1.0);
            let dmu = dlogp * z / sigma;
            let s = self.sc.sig[i] as f64;
            self.dzm.push((dmu * 0.5 * s * (1.0 - s)) as f32);
            let verr = self.value[i] as f64 - mb.ret[i] as f64;
            v_acc += verr * verr;
            self.dzv.push((vf * verr / bn) as f32);
        }
        let pg_loss = pg_acc / bn;
        let v_loss = 0.5 * v_acc / bn;
        let entropy = 0.5 + HALF_LN_2PI + ls;
        let loss = pg_loss + vf * v_loss - ent_coef * entropy;
        self.grad[layout.log_std] = (dls_acc - ent_coef) as f32;

        // -- head parameter gradients + dL/d(last activation) -----------
        let dm = *layout.dims.last().expect("dims is never empty");
        let act_last: &[f32] = self.sc.acts.last().expect("at least one hidden layer");
        self.dh.clear();
        self.dh.resize(b * dm, 0.0);
        let hw = &self.theta[layout.mean_w..layout.mean_w + dm];
        let vw = &self.theta[layout.value_w..layout.value_w + dm];
        for i in 0..b {
            let (gm, gv) = (self.dzm[i], self.dzv[i]);
            let h = &act_last[i * dm..(i + 1) * dm];
            let dh = &mut self.dh[i * dm..(i + 1) * dm];
            for j in 0..dm {
                self.grad[layout.mean_w + j] += h[j] * gm;
                self.grad[layout.value_w + j] += h[j] * gv;
                dh[j] = gm * hw[j] + gv * vw[j];
            }
            self.grad[layout.mean_b] += gm;
            self.grad[layout.value_b] += gv;
        }

        // -- trunk backprop ---------------------------------------------
        for l in (0..layout.layers.len()).rev() {
            let (din, dout) = (layout.dims[l], layout.dims[l + 1]);
            let (w_off, b_off) = layout.layers[l];
            // dZ = dH ∘ tanh'(Z) = dH ∘ (1 - A²)
            let a_l = &self.sc.acts[l];
            self.dz.clear();
            self.dz
                .extend(self.dh.iter().zip(a_l).map(|(&dh, &a)| dh * (1.0 - a * a)));
            // dW_l = X_lᵀ · dZ
            let x: &[f32] = if l == 0 { mb.obs } else { &self.sc.acts[l - 1] };
            gemm::gemm_tn(
                din,
                b,
                dout,
                x,
                &self.dz,
                &mut self.grad[w_off..w_off + din * dout],
            );
            // db_l = column sums of dZ
            for row in self.dz.chunks_exact(dout) {
                for (g, &d) in self.grad[b_off..b_off + dout].iter_mut().zip(row) {
                    *g += d;
                }
            }
            // dX = dZ · W_lᵀ
            if l > 0 {
                self.dh_prev.clear();
                self.dh_prev.resize(b * din, 0.0);
                gemm::gemm_nt(
                    b,
                    dout,
                    din,
                    &self.dz,
                    &self.theta[w_off..w_off + din * dout],
                    &mut self.dh_prev,
                );
                std::mem::swap(&mut self.dh, &mut self.dh_prev);
            }
        }

        Ok(TrainMetrics {
            loss: loss as f32,
            pg_loss: pg_loss as f32,
            v_loss: v_loss as f32,
            entropy: entropy as f32,
            clip_frac: clipped as f32 / b as f32,
            approx_kl: (kl_acc / bn) as f32,
        })
    }

    /// One Adam update from the stored gradient.  Element math runs in
    /// f64 on f32 storage — bitwise deterministic across identically
    /// seeded runs (no threading, no reduction-order ambiguity).
    fn adam_step(&mut self) {
        self.step += 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(self.step);
        let bc2 = 1.0 - ADAM_B2.powf(self.step);
        let lr = self.spec.lr;
        for (((t, g), m), v) in self
            .theta
            .iter_mut()
            .zip(&self.grad)
            .zip(self.m.iter_mut())
            .zip(self.v.iter_mut())
        {
            let g = *g as f64;
            let mn = ADAM_B1 * *m as f64 + (1.0 - ADAM_B1) * g;
            let vn = ADAM_B2 * *v as f64 + (1.0 - ADAM_B2) * g * g;
            *m = mn as f32;
            *v = vn as f32;
            let update = lr * (mn / bc1) / ((vn / bc2).sqrt() + ADAM_EPS);
            *t = (*t as f64 - update) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> NativeSpec {
        NativeSpec {
            features: 6,
            hidden: vec![5, 4],
            minibatch: 7,
            lr: 1e-3,
            clip_eps: 0.2,
            vf_coef: 0.5,
            ent_coef: 0.0,
            log_std_init: (0.05f64).ln(),
            seed: 99,
        }
    }

    /// Random but structured PPO minibatch: actions sampled near the
    /// policy mean, old log-probs offset so a fraction of ratios land
    /// outside the clip interval (both gradient branches exercised).
    fn tiny_batch(spec: &NativeSpec, theta: &[f32], b: usize, seed: u64) -> BatchData {
        let mut rng = Rng::new(seed);
        let obs: Vec<f32> = (0..b * spec.features).map(|_| rng.normal() as f32).collect();
        let policy = NativePolicy::new(spec.clone());
        let out = policy.forward(theta, &obs, b).unwrap();
        let sigma = (out.log_std as f64).exp();
        let act: Vec<f32> = out
            .mean
            .iter()
            .map(|&m| (m as f64 + sigma * rng.normal()) as f32)
            .collect();
        let old_logp: Vec<f32> = act
            .iter()
            .zip(&out.mean)
            .map(|(&a, &m)| {
                let z = (a as f64 - m as f64) / sigma;
                (-0.5 * z * z - out.log_std as f64 - HALF_LN_2PI + rng.range(-0.4, 0.4)) as f32
            })
            .collect();
        let adv: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
        let ret: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
        BatchData { obs, act, old_logp, adv, ret }
    }

    struct BatchData {
        obs: Vec<f32>,
        act: Vec<f32>,
        old_logp: Vec<f32>,
        adv: Vec<f32>,
        ret: Vec<f32>,
    }

    impl BatchData {
        fn mb(&self) -> Minibatch<'_> {
            Minibatch {
                obs: &self.obs,
                act: &self.act,
                old_logp: &self.old_logp,
                adv: &self.adv,
                ret: &self.ret,
            }
        }
    }

    // -- f64 reference implementation (forward + loss only) -------------
    //
    // An independent, naïvely-written f64 twin of the forward pass and
    // the PPO objective.  Central finite differences on THIS function
    // are exact to ~1e-10 relative, so comparing the f32 backprop
    // against them checks the gradient math AND that the fast GEMM
    // forward computes the same function.

    fn ref_forward_f64(
        layout: &Layout,
        theta: &[f64],
        obs: &[f32],
        b: usize,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut x: Vec<f64> = obs.iter().map(|&v| v as f64).collect();
        for (l, &(w_off, b_off)) in layout.layers.iter().enumerate() {
            let (din, dout) = (layout.dims[l], layout.dims[l + 1]);
            let mut y = vec![0f64; b * dout];
            for i in 0..b {
                for o in 0..dout {
                    let mut s = theta[b_off + o];
                    for j in 0..din {
                        s += x[i * din + j] * theta[w_off + j * dout + o];
                    }
                    y[i * dout + o] = s.tanh();
                }
            }
            x = y;
        }
        let dm = *layout.dims.last().unwrap();
        let mut mean = Vec::with_capacity(b);
        let mut value = Vec::with_capacity(b);
        for i in 0..b {
            let h = &x[i * dm..(i + 1) * dm];
            let mut zm = theta[layout.mean_b];
            let mut zv = theta[layout.value_b];
            for j in 0..dm {
                zm += h[j] * theta[layout.mean_w + j];
                zv += h[j] * theta[layout.value_w + j];
            }
            mean.push(0.5 / (1.0 + (-zm).exp()));
            value.push(zv);
        }
        (mean, value)
    }

    fn ref_loss_f64(layout: &Layout, spec: &NativeSpec, theta: &[f64], d: &BatchData) -> f64 {
        let b = d.act.len();
        let (mean, value) = ref_forward_f64(layout, theta, &d.obs, b);
        let ls = theta[layout.log_std];
        let sigma = ls.exp();
        let (mut pg, mut vl) = (0.0f64, 0.0f64);
        for i in 0..b {
            let z = (d.act[i] as f64 - mean[i]) / sigma;
            let logp = -0.5 * z * z - ls - HALF_LN_2PI;
            let ratio = (logp - d.old_logp[i] as f64).exp();
            let adv = d.adv[i] as f64;
            let unclipped = ratio * adv;
            let clamped = ratio.clamp(1.0 - spec.clip_eps, 1.0 + spec.clip_eps) * adv;
            pg += -unclipped.min(clamped);
            let verr = value[i] - d.ret[i] as f64;
            vl += verr * verr;
        }
        let bn = b as f64;
        pg / bn + spec.vf_coef * 0.5 * vl / bn
            - spec.ent_coef * (0.5 + HALF_LN_2PI + ls)
    }

    #[test]
    fn layout_offsets_tile_the_vector_exactly() {
        let l = Layout::new(6, &[5, 4]);
        // 6*5+5 + 5*4+4 + (4+1)*2 + 1
        assert_eq!(l.total, 35 + 24 + 10 + 1);
        assert_eq!(l.layers[0], (0, 30));
        assert_eq!(l.layers[1], (35, 55));
        assert_eq!(l.mean_w, 59);
        assert_eq!(l.mean_b, 63);
        assert_eq!(l.value_w, 64);
        assert_eq!(l.value_b, 68);
        assert_eq!(l.log_std, 69);
        assert_eq!(tiny_spec().param_count(), l.total);
    }

    #[test]
    fn init_is_seed_deterministic_and_bounded() {
        let spec = tiny_spec();
        let a = spec.init_theta();
        let b = spec.init_theta();
        assert_eq!(a.len(), spec.param_count());
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        let mut spec2 = tiny_spec();
        spec2.seed ^= 1;
        assert_ne!(a, spec2.init_theta(), "different seeds must differ");
        assert_eq!(a[Layout::new(6, &[5, 4]).log_std], (0.05f64).ln() as f32);
    }

    #[test]
    fn forward_is_deterministic_and_mean_stays_admissible() {
        let spec = tiny_spec();
        let theta = spec.init_theta();
        let policy = NativePolicy::new(spec.clone());
        let mut rng = Rng::new(3);
        // Extreme inputs: the sigmoid scale must still bound the mean.
        let obs: Vec<f32> = (0..16 * spec.features)
            .map(|_| (rng.normal() * 50.0) as f32)
            .collect();
        let a = policy.forward(&theta, &obs, 16).unwrap();
        let b = policy.forward(&theta, &obs, 16).unwrap();
        assert_eq!(a.mean.len(), 16);
        assert_eq!(a.value.len(), 16);
        assert!(a.mean.iter().zip(&b.mean).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.value.iter().zip(&b.value).all(|(x, y)| x.to_bits() == y.to_bits()));
        for m in &a.mean {
            assert!((0.0..=0.5).contains(m), "mean {m} outside [0, 0.5]");
        }
        assert!(a.value.iter().all(|v| v.is_finite()));
        assert_eq!(a.log_std, theta[Layout::new(6, &[5, 4]).log_std]);
    }

    #[test]
    fn forward_rejects_mismatched_theta_and_obs() {
        let spec = tiny_spec();
        let policy = NativePolicy::new(spec.clone());
        let theta = spec.init_theta();
        assert!(policy.forward(&theta[1..], &[0.0; 6], 1).is_err());
        assert!(policy.forward(&theta, &[0.0; 5], 1).is_err());
        assert!(policy.forward(&theta, &[], 0).is_err());
    }

    #[test]
    fn fast_forward_matches_the_f64_reference() {
        let spec = tiny_spec();
        let theta = spec.init_theta();
        let layout = Layout::new(spec.features, &spec.hidden);
        let d = tiny_batch(&spec, &theta, 9, 17);
        let policy = NativePolicy::new(spec.clone());
        let out = policy.forward(&theta, &d.obs, 9).unwrap();
        let theta64: Vec<f64> = theta.iter().map(|&x| x as f64).collect();
        let (mean64, value64) = ref_forward_f64(&layout, &theta64, &d.obs, 9);
        for i in 0..9 {
            assert!(
                (out.mean[i] as f64 - mean64[i]).abs() < 1e-5,
                "mean[{i}]: {} vs {}",
                out.mean[i],
                mean64[i]
            );
            assert!(
                (out.value[i] as f64 - value64[i]).abs() < 1e-4,
                "value[{i}]: {} vs {}",
                out.value[i],
                value64[i]
            );
        }
    }

    #[test]
    fn backprop_matches_central_finite_differences_per_layer() {
        // The ISSUE-5 acceptance gate: central-difference FD of the full
        // PPO loss against the hand-written backprop, per parameter
        // block (every trunk layer, both heads, log_std), rel error
        // <= 1e-3 at f32.  FD runs on the f64 reference (truncation +
        // roundoff ~1e-9), so the comparison isolates the f32 backprop.
        let spec = tiny_spec();
        let layout = Layout::new(spec.features, &spec.hidden);
        let theta = spec.init_theta();
        // 32 samples with old-logp offsets in ±0.4: ~half the ratios
        // land outside the ±0.2 clip interval, so both min() branches
        // are exercised with overwhelming probability.
        let d = tiny_batch(&spec, &theta, 32, 23);
        let mut trainer = NativeTrainer::new(spec.clone());

        let metrics = trainer.loss_and_grad(&d.mb()).unwrap();
        let theta64: Vec<f64> = theta.iter().map(|&x| x as f64).collect();
        let loss64 = ref_loss_f64(&layout, &spec, &theta64, &d);
        assert!(
            (metrics.loss as f64 - loss64).abs() < 1e-4 * loss64.abs().max(1.0),
            "f32 loss {} vs f64 reference {loss64}",
            metrics.loss
        );

        // Some ratios must actually clip, or the clamped branch is
        // untested.
        assert!(metrics.clip_frac > 0.0, "no sample clipped: weak test data");
        assert!(metrics.clip_frac < 1.0, "every sample clipped: weak test data");

        let mut fd = vec![0f64; layout.total];
        for (p, g) in fd.iter_mut().enumerate() {
            let h = 1e-6 * theta64[p].abs().max(1.0);
            let mut tp = theta64.clone();
            tp[p] += h;
            let lp = ref_loss_f64(&layout, &spec, &tp, &d);
            tp[p] = theta64[p] - h;
            let lm = ref_loss_f64(&layout, &spec, &tp, &d);
            *g = (lp - lm) / (2.0 * h);
        }

        let mut blocks: Vec<(String, usize, usize)> = Vec::new();
        for (l, &(w_off, b_off)) in layout.layers.iter().enumerate() {
            let (din, dout) = (layout.dims[l], layout.dims[l + 1]);
            blocks.push((format!("W{l}"), w_off, w_off + din * dout));
            blocks.push((format!("b{l}"), b_off, b_off + dout));
        }
        let dm = *layout.dims.last().unwrap();
        blocks.push(("mean_w".into(), layout.mean_w, layout.mean_w + dm));
        blocks.push(("mean_b".into(), layout.mean_b, layout.mean_b + 1));
        blocks.push(("value_w".into(), layout.value_w, layout.value_w + dm));
        blocks.push(("value_b".into(), layout.value_b, layout.value_b + 1));
        blocks.push(("log_std".into(), layout.log_std, layout.log_std + 1));

        let grad = trainer.grad();
        for (name, lo, hi) in blocks {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for p in lo..hi {
                let bp = grad[p] as f64;
                num += (bp - fd[p]) * (bp - fd[p]);
                den += fd[p] * fd[p];
            }
            let den = den.sqrt();
            assert!(den > 1e-8, "block {name}: zero FD gradient (vacuous check)");
            let rel = num.sqrt() / den;
            assert!(
                rel <= 1e-3,
                "block {name}: backprop vs FD rel l2 error {rel:.3e} (> 1e-3)"
            );
        }
    }

    #[test]
    fn adam_is_bit_deterministic_across_seeded_runs() {
        let spec = tiny_spec();
        let mut t1 = NativeTrainer::new(spec.clone());
        let mut t2 = NativeTrainer::new(spec.clone());
        let theta0 = t1.theta().to_vec();
        for round in 0..3 {
            let d = tiny_batch(&spec, &theta0, 7, 40 + round);
            let m1 = t1.train_minibatch(&d.mb()).unwrap();
            let m2 = t2.train_minibatch(&d.mb()).unwrap();
            assert_eq!(m1.loss.to_bits(), m2.loss.to_bits(), "round {round}");
            assert_eq!(m1.approx_kl.to_bits(), m2.approx_kl.to_bits());
        }
        assert_eq!(t1.opt_step(), 3.0);
        assert!(
            t1.theta()
                .iter()
                .zip(t2.theta())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "identically-seeded Adam runs must agree bitwise"
        );
        assert!(
            t1.theta().iter().zip(&theta0).any(|(a, b)| a != b),
            "parameters must move"
        );
    }

    #[test]
    fn set_theta_validates_and_resets_the_optimizer() {
        let spec = tiny_spec();
        let mut t = NativeTrainer::new(spec.clone());
        let d = tiny_batch(&spec, &t.theta().to_vec(), 7, 5);
        t.train_minibatch(&d.mb()).unwrap();
        assert_eq!(t.opt_step(), 1.0);
        assert!(t.set_theta(vec![0.0; 3]).is_err(), "wrong length must fail");
        let fresh = spec.init_theta();
        t.set_theta(fresh.clone()).unwrap();
        assert_eq!(t.opt_step(), 0.0);
        assert_eq!(t.theta(), &fresh[..]);
    }

    #[test]
    fn train_metrics_stay_finite_over_many_steps() {
        let spec = tiny_spec();
        let mut t = NativeTrainer::new(spec.clone());
        for round in 0..20 {
            let theta = t.theta().to_vec();
            let d = tiny_batch(&spec, &theta, 7, 100 + round);
            let m = t.train_minibatch(&d.mb()).unwrap();
            for (name, x) in [
                ("loss", m.loss),
                ("pg", m.pg_loss),
                ("vf", m.v_loss),
                ("entropy", m.entropy),
                ("clip_frac", m.clip_frac),
                ("kl", m.approx_kl),
            ] {
                assert!(x.is_finite(), "round {round}: {name} = {x}");
            }
        }
        assert!(t.theta().iter().all(|x| x.is_finite()));
    }
}
