//! Cache-blocked f32 GEMM micro-kernels for the native policy/trainer.
//!
//! Three row-major accumulate kernels cover everything an MLP
//! forward/backward needs:
//!
//! * [`gemm_nn`] — `C += A·B`      (forward:  `Z = X·W`)
//! * [`gemm_tn`] — `C += Aᵀ·B`     (backward: `dW = Xᵀ·dZ`)
//! * [`gemm_nt`] — `C += A·Bᵀ`     (backward: `dX = dZ·Wᵀ`)
//!
//! All three stream the shared panel through a `KC`-deep k-block so it
//! stays cache-resident across the outer loop.  The inner loops are
//! written once against [`F32x8`] and instantiated twice — a scalar
//! symbol and an `#[target_feature(enable = "avx2")]` symbol — with the
//! level picked at runtime ([`crate::util::simd::level`], overridable via
//! `RELEXI_SIMD=scalar`).  `gemm_nn` additionally retires two C rows per
//! pass over the B panel (register-level reuse of the B row).
//!
//! Macro-tile threading: large multiplies split their C rows (and the
//! matching A rows) into disjoint blocks across the persistent worker
//! pool (`[hpc] threads` / `RELEXI_THREADS`).  Row partitioning never
//! changes per-element arithmetic order, so results are **bit-identical**
//! for every thread count — the Adam bit-determinism gate holds under
//! threading.  Small multiplies stay serial ([`thread_rows`]).
//!
//! All kernels *accumulate* into `C`; callers zero (or bias-fill) first.

use crate::util::pool::{self, Pool};
use crate::util::simd::{self, F32x8, Level};

/// Depth of the k-blocking: `KC` rows of the streamed panel (`KC * n`
/// floats) stay L1/L2-resident while a block is consumed.
const KC: usize = 128;

/// Minimum C rows per threaded block (below this the per-task overhead
/// dominates the 2-row retire pattern's useful work).
const MIN_THREAD_ROWS: usize = 8;

/// Minimum `m*k*n` mul-adds before posting a job beats running serial.
const MIN_THREAD_WORK: usize = 1 << 16;

/// Row-block size when threading pays off, else `None` (stay serial).
fn thread_rows(lanes: usize, m: usize, k: usize, n: usize) -> Option<usize> {
    if lanes <= 1 || m < 2 * MIN_THREAD_ROWS || m * k * n < MIN_THREAD_WORK {
        return None;
    }
    // ~2 blocks per lane bounds tail imbalance; the floor keeps blocks
    // from shrinking below the retire pattern's sweet spot.
    let blocks = (2 * lanes).min(m / MIN_THREAD_ROWS).max(2);
    Some((m + blocks - 1) / blocks)
}

// ---------------------------------------------------------------------------
// Kernel bodies: written once, instantiated per dispatch level.  Under
// `#[target_feature(enable = "avx2")]` the compiler turns the F32x8 array
// ops into 256-bit code; the arithmetic DAG is identical either way (no
// fast-math, no implicit FMA contraction), so the two instantiations are
// bit-identical and `Level::Scalar` is the reference semantics.
// ---------------------------------------------------------------------------

/// `C (m×n) += A (m×k) · B (k×n)` — the caller has already sliced `a`/`c`
/// to the row block being retired.
#[inline(always)]
fn nn_body(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let n8 = n - n % F32x8::LANES;
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        // Two C rows at a time: each B-panel row is loaded once per pair.
        let mut i = 0;
        while i + 2 <= m {
            let (c0, c1) = c[i * n..(i + 2) * n].split_at_mut(n);
            for l in 0..kb {
                let a0 = a[i * k + k0 + l];
                let a1 = a[(i + 1) * k + k0 + l];
                let (va0, va1) = (F32x8::splat(a0), F32x8::splat(a1));
                let br = &b[(k0 + l) * n..(k0 + l) * n + n];
                let mut j = 0;
                while j < n8 {
                    let bv = F32x8::load(&br[j..]);
                    F32x8::load(&c0[j..]).add(va0.mul(bv)).store(&mut c0[j..]);
                    F32x8::load(&c1[j..]).add(va1.mul(bv)).store(&mut c1[j..]);
                    j += F32x8::LANES;
                }
                for j in n8..n {
                    c0[j] += a0 * br[j];
                    c1[j] += a1 * br[j];
                }
            }
            i += 2;
        }
        if i < m {
            let c0 = &mut c[i * n..(i + 1) * n];
            for l in 0..kb {
                let a0 = a[i * k + k0 + l];
                let va0 = F32x8::splat(a0);
                let br = &b[(k0 + l) * n..(k0 + l) * n + n];
                let mut j = 0;
                while j < n8 {
                    let bv = F32x8::load(&br[j..]);
                    F32x8::load(&c0[j..]).add(va0.mul(bv)).store(&mut c0[j..]);
                    j += F32x8::LANES;
                }
                for j in n8..n {
                    c0[j] += a0 * br[j];
                }
            }
        }
        k0 += kb;
    }
}

/// `C (m×n) += Aᵀ·B` with `A (k×m)` full-height (k rows) and `c` sliced to
/// rows `[i0, i0+m)` of the logical C.
#[inline(always)]
fn tn_body(i0: usize, m: usize, k: usize, n: usize, ma: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let n8 = n - n % F32x8::LANES;
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let ci = &mut c[i * n..(i + 1) * n];
            for l in k0..k0 + kb {
                let ai = a[l * ma + i0 + i];
                let vai = F32x8::splat(ai);
                let br = &b[l * n..l * n + n];
                let mut j = 0;
                while j < n8 {
                    let bv = F32x8::load(&br[j..]);
                    F32x8::load(&ci[j..]).add(vai.mul(bv)).store(&mut ci[j..]);
                    j += F32x8::LANES;
                }
                for j in n8..n {
                    ci[j] += ai * br[j];
                }
            }
        }
        k0 += kb;
    }
}

/// `C (m×n) += A·Bᵀ` with `A (m×k)`/`B (n×k)`; `a`/`c` sliced to the row
/// block.  Lane-parallel dot with one vector accumulator and the fixed
/// `hsum` tree — a different association than a scalar running sum, hence
/// the f32-tolerance (not bitwise) contract against naive references.
#[inline(always)]
fn nt_body(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        let kb8 = kb - kb % F32x8::LANES;
        for i in 0..m {
            let ar = &a[i * k + k0..i * k + k0 + kb];
            let ci = &mut c[i * n..(i + 1) * n];
            for (j, x) in ci.iter_mut().enumerate() {
                let br = &b[j * k + k0..j * k + k0 + kb];
                let mut acc = F32x8::splat(0.0);
                let mut l = 0;
                while l < kb8 {
                    acc = acc.add(F32x8::load(&ar[l..]).mul(F32x8::load(&br[l..])));
                    l += F32x8::LANES;
                }
                let mut tail = 0.0f32;
                for l in kb8..kb {
                    tail += ar[l] * br[l];
                }
                *x += acc.hsum() + tail;
            }
        }
        k0 += kb;
    }
}

macro_rules! instantiate {
    ($scalar:ident, $avx2:ident, $body:ident ( $($arg:ident : $ty:ty),* )) => {
        fn $scalar($($arg: $ty),*) {
            $body($($arg),*)
        }
        #[cfg(target_arch = "x86_64")]
        #[target_feature(enable = "avx2")]
        unsafe fn $avx2($($arg: $ty),*) {
            $body($($arg),*)
        }
    };
}

instantiate!(nn_scalar, nn_avx2, nn_body(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]));
instantiate!(tn_scalar, tn_avx2, tn_body(i0: usize, m: usize, k: usize, n: usize, ma: usize, a: &[f32], b: &[f32], c: &mut [f32]));
instantiate!(nt_scalar, nt_avx2, nt_body(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]));

#[inline]
fn nn_dispatch(level: Level, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    match level {
        // SAFETY: Level::Avx2 is only ever produced by the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { nn_avx2(m, k, n, a, b, c) },
        _ => nn_scalar(m, k, n, a, b, c),
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn tn_dispatch(
    level: Level,
    i0: usize,
    m: usize,
    k: usize,
    n: usize,
    ma: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    match level {
        // SAFETY: Level::Avx2 is only ever produced by the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { tn_avx2(i0, m, k, n, ma, a, b, c) },
        _ => tn_scalar(i0, m, k, n, ma, a, b, c),
    }
}

#[inline]
fn nt_dispatch(level: Level, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    match level {
        // SAFETY: Level::Avx2 is only ever produced by the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { nt_avx2(m, k, n, a, b, c) },
        _ => nt_scalar(m, k, n, a, b, c),
    }
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// `C (m×n) += A (m×k) · B (k×n)`, all row-major.
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_nn_with(simd::level(), &pool::global(), m, k, n, a, b, c)
}

/// [`gemm_nn`] with an explicit dispatch level and pool (bench A/B,
/// determinism tests).
pub fn gemm_nn_with(
    level: Level,
    pool: &Pool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A must be m x k");
    assert_eq!(b.len(), k * n, "B must be k x n");
    assert_eq!(c.len(), m * n, "C must be m x n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    match thread_rows(pool.threads(), m, k, n) {
        Some(rows) => pool.parallel_chunks_mut(c, rows * n, |blk, c_blk| {
            let i0 = blk * rows;
            let mb = c_blk.len() / n;
            nn_dispatch(level, mb, k, n, &a[i0 * k..(i0 + mb) * k], b, c_blk);
        }),
        None => nn_dispatch(level, m, k, n, a, b, c),
    }
}

/// `C (m×n) += Aᵀ·B` with `A (k×m)` and `B (k×n)`, all row-major.
///
/// The weight-gradient kernel: `dW (in×out) = Xᵀ (B×in)ᵀ · dZ (B×out)`.
pub fn gemm_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_tn_with(simd::level(), &pool::global(), m, k, n, a, b, c)
}

/// [`gemm_tn`] with an explicit dispatch level and pool.
pub fn gemm_tn_with(
    level: Level,
    pool: &Pool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), k * m, "A must be k x m");
    assert_eq!(b.len(), k * n, "B must be k x n");
    assert_eq!(c.len(), m * n, "C must be m x n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    match thread_rows(pool.threads(), m, k, n) {
        Some(rows) => pool.parallel_chunks_mut(c, rows * n, |blk, c_blk| {
            let i0 = blk * rows;
            let mb = c_blk.len() / n;
            tn_dispatch(level, i0, mb, k, n, m, a, b, c_blk);
        }),
        None => tn_dispatch(level, 0, m, k, n, m, a, b, c),
    }
}

/// `C (m×n) += A·Bᵀ` with `A (m×k)` and `B (n×k)`, all row-major.
///
/// The input-gradient kernel: `dX (B×in) = dZ (B×out) · W (in×out)ᵀ`.
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_nt_with(simd::level(), &pool::global(), m, k, n, a, b, c)
}

/// [`gemm_nt`] with an explicit dispatch level and pool.
pub fn gemm_nt_with(
    level: Level,
    pool: &Pool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A must be m x k");
    assert_eq!(b.len(), n * k, "B must be n x k");
    assert_eq!(c.len(), m * n, "C must be m x n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    match thread_rows(pool.threads(), m, k, n) {
        Some(rows) => pool.parallel_chunks_mut(c, rows * n, |blk, c_blk| {
            let i0 = blk * rows;
            let mb = c_blk.len() / n;
            nt_dispatch(level, mb, k, n, &a[i0 * k..(i0 + mb) * k], b, c_blk);
        }),
        None => nt_dispatch(level, m, k, n, a, b, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for l in 0..k {
                    s += a[i * k + l] as f64 * b[l * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, label: &str) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let scale = w.abs().max(1.0);
            assert!(
                (g - w).abs() <= tol * scale,
                "{label}[{i}]: {g} vs {w}"
            );
        }
    }

    #[test]
    fn nn_matches_naive_across_odd_shapes() {
        let mut rng = Rng::new(1);
        // Shapes straddle the KC block boundary and the 2-row unroll.
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (2, KC, 4), (5, KC + 3, 9), (8, 300, 17)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c);
            assert_close(&c, &naive_nn(m, k, n, &a, &b), 1e-5, "nn");
        }
    }

    #[test]
    fn tn_is_a_transposed_nn() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(4, 6, 3), (7, KC + 5, 2), (1, 50, 50)] {
            let a = fill(&mut rng, k * m); // k x m
            let b = fill(&mut rng, k * n);
            // Transpose A explicitly and compare against nn.
            let mut at = vec![0f32; m * k];
            for l in 0..k {
                for i in 0..m {
                    at[i * k + l] = a[l * m + i];
                }
            }
            let mut c = vec![0f32; m * n];
            gemm_tn(m, k, n, &a, &b, &mut c);
            assert_close(&c, &naive_nn(m, k, n, &at, &b), 1e-5, "tn");
        }
    }

    #[test]
    fn nt_is_a_transposed_nn() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(4, 6, 3), (3, KC + 7, 5), (6, 33, 1)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, n * k); // n x k
            let mut bt = vec![0f32; k * n];
            for j in 0..n {
                for l in 0..k {
                    bt[l * n + j] = b[j * k + l];
                }
            }
            let mut c = vec![0f32; m * n];
            gemm_nt(m, k, n, &a, &b, &mut c);
            assert_close(&c, &naive_nn(m, k, n, &a, &bt), 1e-5, "nt");
        }
    }

    #[test]
    fn kernels_accumulate_instead_of_overwriting() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (3, 5, 4);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut c = vec![1.0f32; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c);
        let want: Vec<f32> = naive_nn(m, k, n, &a, &b).iter().map(|x| x + 1.0).collect();
        assert_close(&c, &want, 1e-5, "accumulate");
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut c = vec![2.0f32; 0];
        gemm_nn(0, 3, 0, &[], &[0.0; 0], &mut c);
        let mut c2 = vec![5.0f32; 6];
        gemm_nn(2, 0, 3, &[], &[], &mut c2);
        assert!(c2.iter().all(|&x| x == 5.0), "k=0 must leave C untouched");
    }

    /// The detected level must agree with the scalar reference: bitwise
    /// for the lane-parallel kernels (same arithmetic DAG), f32 tolerance
    /// for the reduction kernel (`hsum` tree vs running sum is still the
    /// same on both levels, so this holds bitwise too — asserted at
    /// tolerance per the dispatch contract).
    #[test]
    fn simd_level_agrees_with_scalar_reference() {
        let mut rng = Rng::new(5);
        let solo = Pool::new(1);
        let detected = simd::level();
        for &(m, k, n) in &[(3, 7, 5), (5, KC + 3, 9), (8, 300, 17), (2, 40, 64)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let mut c_ref = vec![0f32; m * n];
            let mut c_simd = vec![0f32; m * n];
            gemm_nn_with(Level::Scalar, &solo, m, k, n, &a, &b, &mut c_ref);
            gemm_nn_with(detected, &solo, m, k, n, &a, &b, &mut c_simd);
            for i in 0..m * n {
                assert_eq!(c_ref[i].to_bits(), c_simd[i].to_bits(), "nn[{i}]");
            }

            let at = fill(&mut rng, k * m);
            c_ref.iter_mut().for_each(|x| *x = 0.0);
            c_simd.iter_mut().for_each(|x| *x = 0.0);
            gemm_tn_with(Level::Scalar, &solo, m, k, n, &at, &b, &mut c_ref);
            gemm_tn_with(detected, &solo, m, k, n, &at, &b, &mut c_simd);
            for i in 0..m * n {
                assert_eq!(c_ref[i].to_bits(), c_simd[i].to_bits(), "tn[{i}]");
            }

            let bnt = fill(&mut rng, n * k);
            c_ref.iter_mut().for_each(|x| *x = 0.0);
            c_simd.iter_mut().for_each(|x| *x = 0.0);
            gemm_nt_with(Level::Scalar, &solo, m, k, n, &a, &bnt, &mut c_ref);
            gemm_nt_with(detected, &solo, m, k, n, &a, &bnt, &mut c_simd);
            assert_close(&c_simd, &c_ref, 1e-6, "nt simd-vs-scalar");
        }
    }

    /// Row-block threading must be bit-identical to serial for every
    /// width — the Adam determinism gate depends on it.
    #[test]
    fn threaded_gemm_is_bit_identical_across_widths() {
        let mut rng = Rng::new(6);
        let (m, k, n) = (64, 200, 33); // big enough to engage thread_rows
        assert!(thread_rows(8, m, k, n).is_some(), "shape must thread");
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let at = fill(&mut rng, k * m);
        let bnt = fill(&mut rng, n * k);
        let level = simd::level();

        let run = |threads: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let p = Pool::new(threads);
            let mut c1 = vec![0f32; m * n];
            let mut c2 = vec![0f32; m * n];
            let mut c3 = vec![0f32; m * n];
            gemm_nn_with(level, &p, m, k, n, &a, &b, &mut c1);
            gemm_tn_with(level, &p, m, k, n, &at, &b, &mut c2);
            gemm_nt_with(level, &p, m, k, n, &a, &bnt, &mut c3);
            (c1, c2, c3)
        };
        let base = run(1);
        for threads in [2, 8] {
            let got = run(threads);
            for i in 0..m * n {
                assert_eq!(base.0[i].to_bits(), got.0[i].to_bits(), "nn[{i}] @{threads}");
                assert_eq!(base.1[i].to_bits(), got.1[i].to_bits(), "tn[{i}] @{threads}");
                assert_eq!(base.2[i].to_bits(), got.2[i].to_bits(), "nt[{i}] @{threads}");
            }
        }
    }
}
