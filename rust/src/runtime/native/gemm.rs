//! Cache-blocked f32 GEMM micro-kernels for the native policy/trainer.
//!
//! Three row-major accumulate kernels cover everything an MLP
//! forward/backward needs:
//!
//! * [`gemm_nn`] — `C += A·B`      (forward:  `Z = X·W`)
//! * [`gemm_tn`] — `C += Aᵀ·B`     (backward: `dW = Xᵀ·dZ`)
//! * [`gemm_nt`] — `C += A·Bᵀ`     (backward: `dX = dZ·Wᵀ`)
//!
//! All three stream the shared panel through a `KC`-deep k-block so it
//! stays cache-resident across the outer loop, and keep the inner loop a
//! contiguous axpy/dot over zipped slices — the shape rustc/LLVM
//! auto-vectorizes.  `gemm_nn` additionally retires two C rows per pass
//! over the B panel (register-level reuse of the B row).  Sizes here are
//! MLP-scale (k up to ~1.6k features, n up to a few hundred hidden
//! units), so the single k-block level is the one that matters; there is
//! deliberately no threading — the trainer parallelism axis is the env
//! pool, not the update step.
//!
//! All kernels *accumulate* into `C`; callers zero (or bias-fill) first.

/// Depth of the k-blocking: `KC` rows of the streamed panel (`KC * n`
/// floats) stay L1/L2-resident while a block is consumed.
const KC: usize = 128;

/// `C (m×n) += A (m×k) · B (k×n)`, all row-major.
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A must be m x k");
    assert_eq!(b.len(), k * n, "B must be k x n");
    assert_eq!(c.len(), m * n, "C must be m x n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        // Two C rows at a time: each B-panel row is loaded once per pair.
        let mut i = 0;
        while i + 2 <= m {
            let (c0, c1) = c[i * n..(i + 2) * n].split_at_mut(n);
            for l in 0..kb {
                let a0 = a[i * k + k0 + l];
                let a1 = a[(i + 1) * k + k0 + l];
                let br = &b[(k0 + l) * n..(k0 + l) * n + n];
                for ((x0, x1), &bv) in c0.iter_mut().zip(c1.iter_mut()).zip(br) {
                    *x0 += a0 * bv;
                    *x1 += a1 * bv;
                }
            }
            i += 2;
        }
        if i < m {
            let c0 = &mut c[i * n..(i + 1) * n];
            for l in 0..kb {
                let a0 = a[i * k + k0 + l];
                let br = &b[(k0 + l) * n..(k0 + l) * n + n];
                for (x0, &bv) in c0.iter_mut().zip(br) {
                    *x0 += a0 * bv;
                }
            }
        }
        k0 += kb;
    }
}

/// `C (m×n) += Aᵀ·B` with `A (k×m)` and `B (k×n)`, all row-major.
///
/// The weight-gradient kernel: `dW (in×out) = Xᵀ (B×in)ᵀ · dZ (B×out)`.
pub fn gemm_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), k * m, "A must be k x m");
    assert_eq!(b.len(), k * n, "B must be k x n");
    assert_eq!(c.len(), m * n, "C must be m x n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let ci = &mut c[i * n..(i + 1) * n];
            for l in k0..k0 + kb {
                let ai = a[l * m + i];
                let br = &b[l * n..l * n + n];
                for (x, &bv) in ci.iter_mut().zip(br) {
                    *x += ai * bv;
                }
            }
        }
        k0 += kb;
    }
}

/// `C (m×n) += A·Bᵀ` with `A (m×k)` and `B (n×k)`, all row-major.
///
/// The input-gradient kernel: `dX (B×in) = dZ (B×out) · W (in×out)ᵀ`.
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A must be m x k");
    assert_eq!(b.len(), n * k, "B must be n x k");
    assert_eq!(c.len(), m * n, "C must be m x n");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let ar = &a[i * k + k0..i * k + k0 + kb];
            let ci = &mut c[i * n..(i + 1) * n];
            for (j, x) in ci.iter_mut().enumerate() {
                let br = &b[j * k + k0..j * k + k0 + kb];
                // 4-way unrolled dot: independent accumulators keep the
                // FMA chain out of the loop-carried dependency.
                let mut acc = [0.0f32; 4];
                let mut chunks_a = ar.chunks_exact(4);
                let mut chunks_b = br.chunks_exact(4);
                for (ca, cb) in (&mut chunks_a).zip(&mut chunks_b) {
                    acc[0] += ca[0] * cb[0];
                    acc[1] += ca[1] * cb[1];
                    acc[2] += ca[2] * cb[2];
                    acc[3] += ca[3] * cb[3];
                }
                let mut tail = 0.0f32;
                for (&av, &bv) in chunks_a.remainder().iter().zip(chunks_b.remainder()) {
                    tail += av * bv;
                }
                *x += (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail;
            }
        }
        k0 += kb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for l in 0..k {
                    s += a[i * k + l] as f64 * b[l * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, label: &str) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let scale = w.abs().max(1.0);
            assert!(
                (g - w).abs() <= tol * scale,
                "{label}[{i}]: {g} vs {w}"
            );
        }
    }

    #[test]
    fn nn_matches_naive_across_odd_shapes() {
        let mut rng = Rng::new(1);
        // Shapes straddle the KC block boundary and the 2-row unroll.
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (2, KC, 4), (5, KC + 3, 9), (8, 300, 17)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c);
            assert_close(&c, &naive_nn(m, k, n, &a, &b), 1e-5, "nn");
        }
    }

    #[test]
    fn tn_is_a_transposed_nn() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(4, 6, 3), (7, KC + 5, 2), (1, 50, 50)] {
            let a = fill(&mut rng, k * m); // k x m
            let b = fill(&mut rng, k * n);
            // Transpose A explicitly and compare against nn.
            let mut at = vec![0f32; m * k];
            for l in 0..k {
                for i in 0..m {
                    at[i * k + l] = a[l * m + i];
                }
            }
            let mut c = vec![0f32; m * n];
            gemm_tn(m, k, n, &a, &b, &mut c);
            assert_close(&c, &naive_nn(m, k, n, &at, &b), 1e-5, "tn");
        }
    }

    #[test]
    fn nt_is_a_transposed_nn() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(4, 6, 3), (3, KC + 7, 5), (6, 33, 1)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, n * k); // n x k
            let mut bt = vec![0f32; k * n];
            for j in 0..n {
                for l in 0..k {
                    bt[l * n + j] = b[j * k + l];
                }
            }
            let mut c = vec![0f32; m * n];
            gemm_nt(m, k, n, &a, &b, &mut c);
            assert_close(&c, &naive_nn(m, k, n, &a, &bt), 1e-5, "nt");
        }
    }

    #[test]
    fn kernels_accumulate_instead_of_overwriting() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (3, 5, 4);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let mut c = vec![1.0f32; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c);
        let want: Vec<f32> = naive_nn(m, k, n, &a, &b).iter().map(|x| x + 1.0).collect();
        assert_close(&c, &want, 1e-5, "accumulate");
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut c = vec![2.0f32; 0];
        gemm_nn(0, 3, 0, &[], &[0.0; 0], &mut c);
        let mut c2 = vec![5.0f32; 6];
        gemm_nn(2, 0, 3, &[], &[], &mut c2);
        assert!(c2.iter().all(|&x| x == 5.0), "k=0 must leave C untouched");
    }
}
