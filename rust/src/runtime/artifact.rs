//! Artifact registry: locates and describes the AOT outputs emitted by
//! `python/compile/aot.py` into `artifacts/` (manifest, parameter vectors,
//! HLO-text modules per batch size).

use crate::util::binio::{read_f32_vec, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// What a compiled module computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(theta, obs[B]) -> (mean[B], log_std, value[B])`
    PolicyFwd,
    /// Full PPO + Adam minibatch update.
    TrainStep,
}

/// One artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    /// Polynomial degree N of the case the module was lowered for.
    pub n: usize,
    /// Static batch size the module was lowered with.
    pub batch: usize,
    pub path: PathBuf,
}

/// Parsed `manifest.json` + artifact directory.
pub struct Registry {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    /// Flat parameter count per N.
    pub param_counts: std::collections::HashMap<usize, usize>,
    /// Hyperparameters recorded at lowering time (lr, clip, ...).
    pub hyper: Json,
}

impl Registry {
    /// Load the registry from an artifacts directory.
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text)?;

        let mut entries = Vec::new();
        for e in j.get("artifacts")?.arr()? {
            let kind = match e.get("kind")?.str()? {
                "policy_fwd" => ArtifactKind::PolicyFwd,
                "train_step" => ArtifactKind::TrainStep,
                other => bail!("unknown artifact kind {other:?}"),
            };
            entries.push(ArtifactEntry {
                kind,
                n: e.get("n")?.num()? as usize,
                batch: e.get("batch")?.num()? as usize,
                path: dir.join(e.get("file")?.str()?),
            });
        }

        let mut param_counts = std::collections::HashMap::new();
        if let Json::Obj(models) = j.get("models")? {
            for (k, v) in models {
                param_counts.insert(
                    k.parse::<usize>().context("model key")?,
                    v.get("param_count")?.num()? as usize,
                );
            }
        }

        Ok(Registry {
            dir: dir.to_path_buf(),
            entries,
            param_counts,
            hyper: j.get("hyperparameters")?.clone(),
        })
    }

    /// All batch sizes available for (kind, n), ascending.
    pub fn batches(&self, kind: ArtifactKind, n: usize) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind && e.n == n)
            .map(|e| e.batch)
            .collect();
        b.sort_unstable();
        b
    }

    /// Artifact path for (kind, n, batch).
    pub fn path(&self, kind: ArtifactKind, n: usize, batch: usize) -> Result<&Path> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.n == n && e.batch == batch)
            .map(|e| e.path.as_path())
            .with_context(|| format!("no artifact for {kind:?} n={n} b={batch}"))
    }

    /// Initial parameter vector for degree N.
    pub fn initial_params(&self, n: usize) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("params0_n{n}.bin"));
        let theta = read_f32_vec(&path)?;
        if let Some(&count) = self.param_counts.get(&n) {
            anyhow::ensure!(
                theta.len() == count,
                "params0_n{n}.bin has {} params, manifest says {count}",
                theta.len()
            );
        }
        Ok(theta)
    }

    /// Test vectors emitted at lowering time (for round-trip tests).
    pub fn testvec(&self) -> Result<Json> {
        let text = std::fs::read_to_string(self.dir.join("testvec.json"))?;
        Json::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn registry_opens_and_lists() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let r = Registry::open(&dir).unwrap();
        let b = r.batches(ArtifactKind::PolicyFwd, 5);
        assert!(b.contains(&64), "expected b64 policy artifact, got {b:?}");
        assert!(!r.batches(ArtifactKind::TrainStep, 5).is_empty());
        // Table 2: ~3,300-parameter trunk, x2 (actor+critic) + log_std.
        assert_eq!(r.param_counts[&5], 2 * 3293 + 1);
        let theta = r.initial_params(5).unwrap();
        assert_eq!(theta.len(), 6587);
        assert!(r.path(ArtifactKind::PolicyFwd, 5, 64).is_ok());
        assert!(r.path(ArtifactKind::PolicyFwd, 5, 7).is_err());
    }
}
