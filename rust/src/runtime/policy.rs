//! Policy-inference runtime: batched evaluation of the compiled
//! `policy_fwd` artifacts with automatic chunking/padding across the
//! available static batch sizes.

use super::artifact::{ArtifactKind, Registry};
use super::executor::{Executable, HostTensor, Runtime};
use anyhow::{Context, Result};

/// Output of a policy evaluation over a batch of element observations.
#[derive(Debug, Clone)]
pub struct PolicyOut {
    /// Gaussian mean per element (the Cs suggestion, in [0, 0.5]).
    pub mean: Vec<f32>,
    /// Global log standard deviation.
    pub log_std: f32,
    /// Critic value per element.
    pub value: Vec<f32>,
}

/// Compiled policy for one polynomial degree N.
pub struct PolicyRuntime {
    /// (batch, executable), ascending by batch.
    exes: Vec<(usize, Executable)>,
    /// Features per sample: (N+1)^3 * 3.
    feat: usize,
    /// Obs tensor trailing dims.
    dims: [i64; 4],
}

impl PolicyRuntime {
    /// Compile every available `policy_fwd` batch size for degree `n`.
    pub fn load(rt: &Runtime, reg: &Registry, n: usize) -> Result<PolicyRuntime> {
        let batches = reg.batches(ArtifactKind::PolicyFwd, n);
        anyhow::ensure!(!batches.is_empty(), "no policy_fwd artifacts for N={n}");
        let mut exes = Vec::new();
        for b in batches {
            let exe = rt.load_hlo(reg.path(ArtifactKind::PolicyFwd, n, b)?)?;
            exes.push((b, exe));
        }
        let p = (n + 1) as i64;
        Ok(PolicyRuntime {
            exes,
            feat: ((n + 1).pow(3) * 3),
            dims: [p, p, p, 3],
        })
    }

    /// Features per sample.
    pub fn features(&self) -> usize {
        self.feat
    }

    /// Evaluate the policy on `n_samples` element observations
    /// (`obs.len() == n_samples * features()`), chunking over the
    /// compiled batch sizes and zero-padding the tail chunk.
    pub fn forward(&self, theta: &[f32], obs: &[f32], n_samples: usize) -> Result<PolicyOut> {
        anyhow::ensure!(
            obs.len() == n_samples * self.feat,
            "obs len {} != {n_samples} x {}",
            obs.len(),
            self.feat
        );
        let theta_t = HostTensor::vec(theta.to_vec());
        let mut mean = Vec::with_capacity(n_samples);
        let mut value = Vec::with_capacity(n_samples);
        let mut log_std = 0.0f32;
        let mut done = 0usize;
        while done < n_samples {
            let remaining = n_samples - done;
            let (b, exe) = self.pick(remaining);
            let take = remaining.min(b);
            let mut chunk = vec![0f32; b * self.feat];
            chunk[..take * self.feat]
                .copy_from_slice(&obs[done * self.feat..(done + take) * self.feat]);
            let shape = vec![
                b as i64,
                self.dims[0],
                self.dims[1],
                self.dims[2],
                self.dims[3],
            ];
            let out = exe
                .run(&[theta_t.clone(), HostTensor::new(shape, chunk)])
                .with_context(|| format!("policy_fwd b={b}"))?;
            anyhow::ensure!(out.len() == 3, "policy_fwd returned {} outputs", out.len());
            mean.extend_from_slice(&out[0].data[..take]);
            log_std = out[1].data[0];
            value.extend_from_slice(&out[2].data[..take]);
            done += take;
        }
        Ok(PolicyOut { mean, log_std, value })
    }

    /// Smallest compiled batch covering `remaining`, else the largest.
    fn pick(&self, remaining: usize) -> (usize, &Executable) {
        for (b, exe) in &self.exes {
            if *b >= remaining {
                return (*b, exe);
            }
        }
        let (b, exe) = self.exes.last().unwrap();
        (*b, exe)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn feature_arithmetic() {
        // The chunking invariants are covered by the integration test
        // against testvec.json (requires artifacts). Here: feature math.
        let p = 6usize;
        assert_eq!(p.pow(3) * 3, 648); // N=5 obs features per element
        let p7 = 8usize;
        assert_eq!(p7.pow(3) * 3, 1536); // N=7
    }
}
