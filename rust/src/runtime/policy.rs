//! Policy-inference runtime: batched evaluation of the compiled
//! `policy_fwd` artifacts with automatic chunking/padding across the
//! available static batch sizes.
//!
//! The event-driven rollout collector produces *variable-size* forward
//! batches (whatever arrived), so the chunk plan matters: filling the
//! largest compiled batch that fits before padding a tail chunk keeps the
//! wasted (zero-padded) FLOPs bounded by one minimal chunk, instead of
//! padding the whole request up to the next compiled size.

use super::artifact::{ArtifactKind, Registry};
use super::executor::{Executable, HostTensor, Runtime};
use anyhow::{Context, Result};
use std::sync::Mutex;

/// Deterministic policy stand-in with the `forward` closure shape the
/// rollout collector consumes (`(obs, n_samples) -> PolicyOut`): mean and
/// value are pure functions of the observation, log_std is fixed.  Used
/// by benches and artifact-free integration tests to drive the full
/// worker-pool/orchestrator stack without compiled artifacts — one shared
/// definition so the bitwise-equivalence test and the bench exercise the
/// same policy.
pub fn stub_policy(obs: &[f32], n_samples: usize) -> Result<PolicyOut> {
    anyhow::ensure!(
        n_samples > 0 && obs.len() % n_samples == 0,
        "obs len {} must split evenly over {n_samples} samples",
        obs.len()
    );
    let feat = obs.len() / n_samples;
    let mut mean = Vec::with_capacity(n_samples);
    let mut value = Vec::with_capacity(n_samples);
    for k in 0..n_samples {
        let s: f32 = obs[k * feat..(k + 1) * feat].iter().map(|x| x.abs()).sum();
        let m = (s / feat as f32).clamp(0.0, 0.4);
        mean.push(m);
        value.push(0.1 * m - 0.05);
    }
    Ok(PolicyOut {
        mean,
        log_std: -1.2,
        value,
    })
}

/// Plan a variable-size request over the compiled batch sizes
/// (`batches` ascending): greedily fill the largest batch that fits, then
/// pad the remainder in the smallest batch that covers it.  Returns
/// `(batch, take)` pairs with `sum(take) == n_samples`.
pub fn plan_chunks(batches: &[usize], n_samples: usize) -> Vec<(usize, usize)> {
    assert!(!batches.is_empty(), "no compiled batch sizes");
    let mut plan = Vec::new();
    let mut remaining = n_samples;
    while remaining > 0 {
        // Largest compiled batch fully covered by the remainder...
        if let Some(&b) = batches.iter().rev().find(|&&b| b <= remaining) {
            plan.push((b, b));
            remaining -= b;
        } else {
            // ...else the smallest batch that covers the (padded) tail.
            let &b = batches
                .iter()
                .find(|&&b| b >= remaining)
                .expect("ascending batches must cover the tail");
            plan.push((b, remaining));
            remaining = 0;
        }
    }
    plan
}

/// Output of a policy evaluation over a batch of element observations.
#[derive(Debug, Clone)]
pub struct PolicyOut {
    /// Gaussian mean per element (the Cs suggestion, in [0, 0.5]).
    pub mean: Vec<f32>,
    /// Global log standard deviation.
    pub log_std: f32,
    /// Critic value per element.
    pub value: Vec<f32>,
}

/// Compiled policy for one polynomial degree N.
pub struct PolicyRuntime {
    /// (batch, executable), ascending by batch.
    exes: Vec<(usize, Executable)>,
    /// Features per sample: (N+1)^3 * 3.
    feat: usize,
    /// Obs tensor trailing dims.
    dims: [i64; 4],
    /// Interned host tensors reused across `forward` calls: the theta
    /// tensor is rebuilt only when the parameters actually changed (once
    /// per training iteration, not once per forward), and the padded
    /// chunk buffer keeps its allocation across chunks and calls.
    scratch: Mutex<FwdScratch>,
}

/// Reused forward-call host tensors (see [`PolicyRuntime::scratch`]).
#[derive(Default)]
struct FwdScratch {
    theta: HostTensor,
    chunk: HostTensor,
}

impl PolicyRuntime {
    /// Compile every available `policy_fwd` batch size for degree `n`.
    pub fn load(rt: &Runtime, reg: &Registry, n: usize) -> Result<PolicyRuntime> {
        let mut batches = reg.batches(ArtifactKind::PolicyFwd, n);
        anyhow::ensure!(!batches.is_empty(), "no policy_fwd artifacts for N={n}");
        batches.sort_unstable(); // plan_chunks requires ascending sizes
        let mut exes = Vec::new();
        for b in batches {
            let exe = rt.load_hlo(reg.path(ArtifactKind::PolicyFwd, n, b)?)?;
            exes.push((b, exe));
        }
        let p = (n + 1) as i64;
        Ok(PolicyRuntime {
            exes,
            feat: ((n + 1).pow(3) * 3),
            dims: [p, p, p, 3],
            scratch: Mutex::new(FwdScratch::default()),
        })
    }

    /// Features per sample.
    pub fn features(&self) -> usize {
        self.feat
    }

    /// Evaluate the policy on `n_samples` element observations
    /// (`obs.len() == n_samples * features()`), chunking over the
    /// compiled batch sizes and zero-padding the tail chunk.
    pub fn forward(&self, theta: &[f32], obs: &[f32], n_samples: usize) -> Result<PolicyOut> {
        let _sp = crate::span!("policy.forward");
        let _t = crate::util::telemetry::HistId::PolicyForward.timer();
        anyhow::ensure!(
            obs.len() == n_samples * self.feat,
            "obs len {} != {n_samples} x {}",
            obs.len(),
            self.feat
        );
        let mut guard = self.scratch.lock().expect("policy forward scratch lock");
        let s = &mut *guard;
        // Intern theta: a sampling phase calls forward many times under
        // one unchanged parameter vector, so the host tensor is rebuilt
        // only when the contents differ (one memcmp vs a fresh to_vec
        // per call).
        if s.theta.data.as_slice() != theta {
            s.theta.refill_vec(theta);
        }
        let mut mean = Vec::with_capacity(n_samples);
        let mut value = Vec::with_capacity(n_samples);
        let mut log_std = 0.0f32;
        let mut done = 0usize;
        let batches: Vec<usize> = self.exes.iter().map(|(b, _)| *b).collect();
        for (b, take) in plan_chunks(&batches, n_samples) {
            let exe = self.exe_for(b);
            s.chunk.data.clear();
            s.chunk
                .data
                .extend_from_slice(&obs[done * self.feat..(done + take) * self.feat]);
            s.chunk.data.resize(b * self.feat, 0.0); // zero the padded tail
            s.chunk.shape.clear();
            s.chunk.shape.extend_from_slice(&[
                b as i64,
                self.dims[0],
                self.dims[1],
                self.dims[2],
                self.dims[3],
            ]);
            let out = exe
                .run_ref(&[&s.theta, &s.chunk])
                .with_context(|| format!("policy_fwd b={b}"))?;
            anyhow::ensure!(out.len() == 3, "policy_fwd returned {} outputs", out.len());
            mean.extend_from_slice(&out[0].data[..take]);
            log_std = out[1].data[0];
            value.extend_from_slice(&out[2].data[..take]);
            done += take;
        }
        Ok(PolicyOut { mean, log_std, value })
    }

    /// The executable compiled for exactly batch `b` (plan entries always
    /// name a compiled size).
    fn exe_for(&self, b: usize) -> &Executable {
        &self
            .exes
            .iter()
            .find(|(eb, _)| *eb == b)
            .expect("plan_chunks only emits compiled batch sizes")
            .1
    }
}

#[cfg(test)]
mod tests {
    use super::plan_chunks;

    #[test]
    fn feature_arithmetic() {
        // The chunking invariants are covered by the integration test
        // against testvec.json (requires artifacts). Here: feature math.
        let p = 6usize;
        assert_eq!(p.pow(3) * 3, 648); // N=5 obs features per element
        let p7 = 8usize;
        assert_eq!(p7.pow(3) * 3, 1536); // N=7
    }

    #[test]
    fn plan_fills_largest_before_padding() {
        let b = [64usize, 256, 1024];
        assert_eq!(plan_chunks(&b, 64), vec![(64, 64)]);
        assert_eq!(plan_chunks(&b, 40), vec![(64, 40)]);
        // 65 pads one element into a second 64-batch, not a 256-batch.
        assert_eq!(plan_chunks(&b, 65), vec![(64, 64), (64, 1)]);
        assert_eq!(plan_chunks(&b, 300), vec![(256, 256), (64, 44)]);
        assert_eq!(
            plan_chunks(&b, 1024 + 256 + 64 + 3),
            vec![(1024, 1024), (256, 256), (64, 64), (64, 3)]
        );
    }

    #[test]
    fn plan_empty_batch_list_panics() {
        // No compiled batch sizes is a build/registry error, not a
        // plannable request — assert the guard fires rather than looping.
        let r = std::panic::catch_unwind(|| plan_chunks(&[], 7));
        assert!(r.is_err());
        // ...including for the degenerate zero-sample request.
        let r = std::panic::catch_unwind(|| plan_chunks(&[], 0));
        assert!(r.is_err());
    }

    #[test]
    fn plan_zero_samples_is_empty() {
        assert!(plan_chunks(&[8, 32], 0).is_empty());
        assert!(plan_chunks(&[1], 0).is_empty());
    }

    #[test]
    fn plan_single_oversized_batch_pads_once() {
        // Only one compiled size, larger than the request: one padded
        // chunk, never an infinite loop or a zero-take entry.
        assert_eq!(plan_chunks(&[256], 10), vec![(256, 10)]);
        assert_eq!(plan_chunks(&[256], 1), vec![(256, 1)]);
        assert_eq!(plan_chunks(&[256], 256), vec![(256, 256)]);
        assert_eq!(plan_chunks(&[256], 257), vec![(256, 256), (256, 1)]);
    }

    #[test]
    fn plan_covers_any_request() {
        let b = [8usize, 32];
        for n in 1..200 {
            let plan = plan_chunks(&b, n);
            let taken: usize = plan.iter().map(|(_, t)| t).sum();
            assert_eq!(taken, n, "plan must cover exactly n={n}");
            for (batch, take) in plan {
                assert!(b.contains(&batch));
                assert!(take <= batch && take > 0);
            }
        }
        assert!(plan_chunks(&b, 0).is_empty());
    }
}
