//! PJRT execution of AOT artifacts: load HLO *text*, compile once on the
//! CPU client, execute many times from the coordinator's hot path.
//!
//! This is the Rust end of the AOT bridge (see `python/compile/aot.py` and
//! /opt/xla-example/load_hlo): HLO text — not serialized protos — is the
//! interchange format because jax >= 0.5 emits 64-bit instruction ids that
//! the image's xla_extension 0.5.1 rejects; the text parser reassigns ids.

use anyhow::{Context, Result};
use std::path::Path;

/// Shared PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        Ok(Executable {
            exe,
            name: path.file_name().unwrap().to_string_lossy().to_string(),
        })
    }
}

/// One compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// A host-side tensor handed to / returned by an executable.  The
/// `Default` value (empty shape, empty data) is only the placeholder
/// state of interned/reused tensors before their first refill — never
/// execute it.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HostTensor {
    pub shape: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Rank-1 tensor.
    pub fn vec(data: Vec<f32>) -> HostTensor {
        HostTensor { shape: vec![data.len() as i64], data }
    }

    /// Scalar.
    pub fn scalar(x: f32) -> HostTensor {
        HostTensor { shape: vec![], data: vec![x] }
    }

    /// Shaped tensor; checks element count.
    pub fn new(shape: Vec<i64>, data: Vec<f32>) -> HostTensor {
        let n: i64 = shape.iter().product();
        assert_eq!(n as usize, data.len(), "shape {shape:?} vs {} elems", data.len());
        HostTensor { shape, data }
    }

    /// Refill as a rank-1 tensor, reusing both allocations — the
    /// interning primitive behind the policy/trainer host-tensor reuse
    /// (no fresh `Vec` per executable call).
    pub fn refill_vec(&mut self, data: &[f32]) {
        self.data.clear();
        self.data.extend_from_slice(data);
        self.shape.clear();
        self.shape.push(data.len() as i64);
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let lit = xla::Literal::vec1(&self.data);
        Ok(lit.reshape(&self.shape)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        Ok(HostTensor {
            shape: shape.dims().to_vec(),
            data: lit.to_vec::<f32>()?,
        })
    }
}

impl Executable {
    /// Execute with f32 host tensors; returns the flattened output tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_ref(&refs)
    }

    /// [`Executable::run`] over borrowed tensors, so callers can keep
    /// their inputs interned across calls (the runtime state and scratch
    /// tensors live in the policy/trainer and are refilled in place, not
    /// cloned into fresh `HostTensor`s per minibatch).
    pub fn run_ref(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.name))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Artifact name (diagnostics).
    pub fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
        let v = HostTensor::vec(vec![1.0, 2.0]);
        assert_eq!(v.shape, vec![2]);
        let s = HostTensor::scalar(3.0);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic]
    fn host_tensor_shape_mismatch() {
        HostTensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn refill_vec_reuses_the_allocation() {
        let mut t = HostTensor::new(vec![2, 2], vec![1.0; 4]);
        let cap = t.data.capacity();
        let ptr = t.data.as_ptr();
        t.refill_vec(&[5.0, 6.0]);
        assert_eq!(t.shape, vec![2]);
        assert_eq!(t.data, vec![5.0, 6.0]);
        assert_eq!(t.data.capacity(), cap, "refill must not reallocate");
        assert_eq!(t.data.as_ptr(), ptr);
    }
}
