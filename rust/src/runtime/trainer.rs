//! Training runtime: owns the flat parameter vector and Adam state and
//! applies the compiled `train_step` artifact (PPO loss + gradients +
//! Adam, all inside one XLA module) minibatch by minibatch.

use super::artifact::{ArtifactKind, Registry};
use super::executor::{Executable, HostTensor, Runtime};
use anyhow::{Context, Result};

/// Metrics returned by one train step (paper-standard PPO diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainMetrics {
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub clip_frac: f32,
    pub approx_kl: f32,
}

/// One PPO minibatch in the layout the artifact expects.
pub struct Minibatch<'a> {
    /// `batch * features` observation block.
    pub obs: &'a [f32],
    pub act: &'a [f32],
    pub old_logp: &'a [f32],
    pub adv: &'a [f32],
    pub ret: &'a [f32],
}

/// Compiled trainer for one polynomial degree N.
pub struct TrainerRuntime {
    exe: Executable,
    /// Static minibatch size the artifact was lowered with.
    pub minibatch: usize,
    feat: usize,
    dims: [i64; 4],
    theta: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: f32,
}

impl TrainerRuntime {
    /// Load the train_step artifact closest to the requested minibatch
    /// size and initialize parameters from `params0_n{n}.bin`.
    pub fn load(rt: &Runtime, reg: &Registry, n: usize, want_batch: usize) -> Result<TrainerRuntime> {
        let batches = reg.batches(ArtifactKind::TrainStep, n);
        anyhow::ensure!(!batches.is_empty(), "no train_step artifacts for N={n}");
        let minibatch = *batches
            .iter()
            .filter(|&&b| b <= want_batch)
            .max()
            .unwrap_or(&batches[0]);
        let exe = rt.load_hlo(reg.path(ArtifactKind::TrainStep, n, minibatch)?)?;
        let theta = reg.initial_params(n)?;
        let len = theta.len();
        let p = (n + 1) as i64;
        Ok(TrainerRuntime {
            exe,
            minibatch,
            feat: (n + 1).pow(3) * 3,
            dims: [p, p, p, 3],
            theta,
            m: vec![0.0; len],
            v: vec![0.0; len],
            step: 0.0,
        })
    }

    /// Current parameters (shared with the policy runtime each call).
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Optimizer step counter.
    pub fn opt_step(&self) -> f32 {
        self.step
    }

    /// Restore parameters (checkpoint load); resets Adam state.
    pub fn set_theta(&mut self, theta: Vec<f32>) {
        assert_eq!(theta.len(), self.theta.len());
        self.theta = theta;
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0.0;
    }

    /// Apply one compiled PPO+Adam step on a minibatch of exactly
    /// `self.minibatch` samples.
    pub fn train_minibatch(&mut self, mb: &Minibatch) -> Result<TrainMetrics> {
        let b = self.minibatch;
        anyhow::ensure!(mb.act.len() == b, "minibatch size {} != {b}", mb.act.len());
        anyhow::ensure!(mb.obs.len() == b * self.feat);
        let shape = vec![b as i64, self.dims[0], self.dims[1], self.dims[2], self.dims[3]];
        let out = self
            .exe
            .run(&[
                HostTensor::vec(self.theta.clone()),
                HostTensor::vec(self.m.clone()),
                HostTensor::vec(self.v.clone()),
                HostTensor::scalar(self.step),
                HostTensor::new(shape, mb.obs.to_vec()),
                HostTensor::vec(mb.act.to_vec()),
                HostTensor::vec(mb.old_logp.to_vec()),
                HostTensor::vec(mb.adv.to_vec()),
                HostTensor::vec(mb.ret.to_vec()),
            ])
            .context("train_step")?;
        anyhow::ensure!(out.len() == 10, "train_step returned {} outputs", out.len());
        self.theta = out[0].data.clone();
        self.m = out[1].data.clone();
        self.v = out[2].data.clone();
        self.step = out[3].data[0];
        Ok(TrainMetrics {
            loss: out[4].data[0],
            pg_loss: out[5].data[0],
            v_loss: out[6].data[0],
            entropy: out[7].data[0],
            clip_frac: out[8].data[0],
            approx_kl: out[9].data[0],
        })
    }
}
