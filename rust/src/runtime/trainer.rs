//! Training runtime (XLA path): owns the flat parameter vector and Adam
//! state and applies the compiled `train_step` artifact (PPO loss +
//! gradients + Adam, all inside one XLA module) minibatch by minibatch.
//!
//! The runtime state lives directly in [`HostTensor`]s: each call hands
//! the executable borrowed tensors ([`Executable::run_ref`]) and then
//! *moves* the returned state tensors back in — no `theta`/`m`/`v` deep
//! copies per minibatch (they used to be cloned into fresh tensors every
//! call).  Minibatch inputs are staged through reusable scratch tensors
//! the same way, so a steady-state train step allocates only what PJRT
//! itself allocates.

use super::artifact::{ArtifactKind, Registry};
use super::executor::{Executable, HostTensor, Runtime};
use anyhow::{Context, Result};

/// Metrics returned by one train step (paper-standard PPO diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainMetrics {
    pub loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub clip_frac: f32,
    pub approx_kl: f32,
}

/// One PPO minibatch in the layout the artifact expects.
pub struct Minibatch<'a> {
    /// `batch * features` observation block.
    pub obs: &'a [f32],
    pub act: &'a [f32],
    pub old_logp: &'a [f32],
    pub adv: &'a [f32],
    pub ret: &'a [f32],
}

/// Compiled trainer for one polynomial degree N.
pub struct TrainerRuntime {
    exe: Executable,
    /// Static minibatch size the artifact was lowered with.
    pub minibatch: usize,
    feat: usize,
    dims: [i64; 4],
    // Runtime state, kept as host tensors so each step passes them by
    // reference and adopts the outputs by move.
    theta: HostTensor,
    m: HostTensor,
    v: HostTensor,
    step: HostTensor,
    // Reused minibatch input scratch (refilled in place per call).
    obs_t: HostTensor,
    act_t: HostTensor,
    logp_t: HostTensor,
    adv_t: HostTensor,
    ret_t: HostTensor,
}

impl TrainerRuntime {
    /// Load the train_step artifact closest to the requested minibatch
    /// size and initialize parameters from `params0_n{n}.bin`.
    pub fn load(rt: &Runtime, reg: &Registry, n: usize, want_batch: usize) -> Result<TrainerRuntime> {
        let batches = reg.batches(ArtifactKind::TrainStep, n);
        anyhow::ensure!(!batches.is_empty(), "no train_step artifacts for N={n}");
        let minibatch = *batches
            .iter()
            .filter(|&&b| b <= want_batch)
            .max()
            .unwrap_or(&batches[0]);
        let exe = rt.load_hlo(reg.path(ArtifactKind::TrainStep, n, minibatch)?)?;
        let theta = reg.initial_params(n)?;
        let len = theta.len();
        let p = (n + 1) as i64;
        Ok(TrainerRuntime {
            exe,
            minibatch,
            feat: (n + 1).pow(3) * 3,
            dims: [p, p, p, 3],
            theta: HostTensor::vec(theta),
            m: HostTensor::vec(vec![0.0; len]),
            v: HostTensor::vec(vec![0.0; len]),
            step: HostTensor::scalar(0.0),
            obs_t: HostTensor::default(),
            act_t: HostTensor::default(),
            logp_t: HostTensor::default(),
            adv_t: HostTensor::default(),
            ret_t: HostTensor::default(),
        })
    }

    /// Current parameters (shared with the policy runtime each call).
    pub fn theta(&self) -> &[f32] {
        &self.theta.data
    }

    /// Optimizer step counter.
    pub fn opt_step(&self) -> f32 {
        self.step.data[0]
    }

    /// Restore parameters (checkpoint load); resets Adam state.  Fails
    /// when the vector length does not match the artifact's parameters.
    pub fn set_theta(&mut self, theta: Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            theta.len() == self.theta.data.len(),
            "checkpoint has {} params, artifact expects {}",
            theta.len(),
            self.theta.data.len()
        );
        self.theta = HostTensor::vec(theta);
        self.m.data.iter_mut().for_each(|x| *x = 0.0);
        self.v.data.iter_mut().for_each(|x| *x = 0.0);
        self.step.data[0] = 0.0;
        Ok(())
    }

    /// Apply one compiled PPO+Adam step on a minibatch of exactly
    /// `self.minibatch` samples.
    pub fn train_minibatch(&mut self, mb: &Minibatch) -> Result<TrainMetrics> {
        let _sp = crate::span!("train.minibatch");
        let _t = crate::util::telemetry::HistId::TrainMinibatch.timer();
        let b = self.minibatch;
        anyhow::ensure!(mb.act.len() == b, "minibatch size {} != {b}", mb.act.len());
        anyhow::ensure!(mb.obs.len() == b * self.feat);
        self.obs_t.data.clear();
        self.obs_t.data.extend_from_slice(mb.obs);
        self.obs_t.shape.clear();
        self.obs_t.shape.extend_from_slice(&[
            b as i64,
            self.dims[0],
            self.dims[1],
            self.dims[2],
            self.dims[3],
        ]);
        self.act_t.refill_vec(mb.act);
        self.logp_t.refill_vec(mb.old_logp);
        self.adv_t.refill_vec(mb.adv);
        self.ret_t.refill_vec(mb.ret);
        let mut out = self
            .exe
            .run_ref(&[
                &self.theta,
                &self.m,
                &self.v,
                &self.step,
                &self.obs_t,
                &self.act_t,
                &self.logp_t,
                &self.adv_t,
                &self.ret_t,
            ])
            .context("train_step")?;
        anyhow::ensure!(out.len() == 10, "train_step returned {} outputs", out.len());
        // Adopt the new runtime state by move (the former clones were
        // four full parameter-sized copies per minibatch).
        let mut state = out.drain(0..4);
        self.theta = state.next().expect("drained exactly 4");
        self.m = state.next().expect("drained exactly 4");
        self.v = state.next().expect("drained exactly 4");
        // Keep our rank-0 step tensor; only adopt the counter value.
        self.step.data[0] = state.next().expect("drained exactly 4").data[0];
        drop(state);
        Ok(TrainMetrics {
            loss: out[0].data[0],
            pg_loss: out[1].data[0],
            v_loss: out[2].data[0],
            entropy: out[3].data[0],
            clip_frac: out[4].data[0],
            approx_kl: out[5].data[0],
        })
    }
}
