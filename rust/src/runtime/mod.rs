//! The PJRT runtime (DESIGN.md S12): loads the HLO-text artifacts that
//! `make artifacts` produced from the JAX/Pallas layers and executes them
//! from the coordinator's hot path.  Python never runs at training time —
//! the compiled policy and train-step modules are the only ML code paths.

pub mod artifact;
pub mod executor;
pub mod policy;
pub mod trainer;

pub use artifact::{ArtifactKind, Registry};
pub use executor::{Executable, HostTensor, Runtime};
pub use policy::{plan_chunks, stub_policy, PolicyOut, PolicyRuntime};
pub use trainer::{Minibatch, TrainMetrics, TrainerRuntime};
