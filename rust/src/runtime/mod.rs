//! The policy/trainer runtime layer: two interchangeable ML execution
//! backends behind the [`Policy`] / [`Trainer`] trait seam ([`api`]),
//! selected by the `runtime.backend` config field:
//!
//! * **`"xla"`** (the original PJRT path, DESIGN.md S12): loads the
//!   HLO-text artifacts that `make artifacts` produced from the
//!   JAX/Pallas layers ([`artifact`], [`executor`]) and executes the
//!   compiled `policy_fwd` ([`policy`]) and `train_step` ([`trainer`])
//!   modules from the coordinator's hot path.  Python never runs at
//!   training time.  Artifact shapes are fixed at lowering time, so
//!   this path serves exactly the observation shapes it was built for
//!   (today: the LES element shapes, N in {5, 7}) and needs the
//!   artifacts directory on disk.
//! * **`"native"`** ([`native`]): a pure-Rust MLP policy + clipped-PPO
//!   trainer — cache-blocked f32 GEMM, hand-written backprop, Adam —
//!   that sizes its input layer from the environment pool at
//!   construction.  Zero artifacts, any registered CFD backend, same
//!   flat-`theta` checkpoint format, same [`TrainMetrics`] diagnostics.
//!
//! Both backends obey one contract (spelled out in [`api`] and enforced
//! against every registered backend by `tests/conformance_policy.rs`):
//! the trainer owns the flat f32 parameter vector, the policy evaluates
//! deterministically under an explicitly passed `theta`, means stay in
//! the admissible `[0, 0.5]` range, and one `train_minibatch` is one
//! optimizer step.

pub mod api;
pub mod artifact;
pub mod executor;
pub mod native;
pub mod policy;
pub mod trainer;

pub use api::{runtime_from_config, Policy, Trainer};
pub use artifact::{ArtifactKind, Registry};
pub use executor::{Executable, HostTensor, Runtime};
pub use native::{NativePolicy, NativeSpec, NativeTrainer};
pub use policy::{plan_chunks, stub_policy, PolicyOut, PolicyRuntime};
pub use trainer::{Minibatch, TrainMetrics, TrainerRuntime};
