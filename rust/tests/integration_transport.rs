//! Integration: the transport seam behind the orchestrator store.
//!
//! Four layers, matching the PR-7 acceptance gates:
//!
//! * wire-codec robustness — random garbage, truncated frames and
//!   single-byte mutations of every `Request`/`Response`/`Value`
//!   encoding must error (or decode consistently), never panic;
//! * a transport-conformance suite running the store contract
//!   (exactly-once `wait_take` under racing waiters, put/clear races,
//!   subscription add/remove deltas) against all three transports
//!   through the same `Arc<dyn Transport>` seam;
//! * the loopback-TCP smoke: a trainer plus real `relexi env-worker`
//!   OS processes run an 8-env Burgers iteration whose episodes are
//!   bit-identical to the in-process threads pool at the same seed;
//! * bounded worker teardown: an env-worker whose trainer dies without
//!   posting the stop flag exits on its own within the reconnect bound
//!   — both idle and with episodes in flight;
//! * chaos (PR-8 acceptance): deterministic `[fault]` plans kill a
//!   worker mid-wave or before its first begin — the supervisor must
//!   respawn + replay to bit-identical episodes, and an exhausted
//!   respawn budget must degrade to a short wave instead of aborting.

use relexi::config::{BurgersConfig, EnvVariant, RunConfig};
use relexi::coordinator::{EnvPool, Rollouts};
use relexi::orchestrator::protocol::{ctl_begin_key, ctl_hello_key, encode_begin};
use relexi::orchestrator::transport::{
    frame_len, InprocTransport, RemoteTransport, Request, Response, Transport, MAX_FRAME,
};
use relexi::orchestrator::{Orchestrator, Protocol, StatsSnapshot, Value};
use relexi::rl::Episode;
use relexi::runtime::stub_policy;
use relexi::util::Rng;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------- codec

fn sample_requests() -> Vec<Request> {
    vec![
        Request::Put {
            key: "k:put".into(),
            value: Value::tensor(vec![2, 3], vec![0.5; 6]),
        },
        Request::Put {
            key: "k:bytes".into(),
            value: Value::bytes(vec![0, 1, 2, 254, 255]),
        },
        Request::Get { key: "k".into() },
        Request::Take { key: "k".into() },
        Request::Exists { key: "k".into() },
        Request::Delete { key: "k".into() },
        Request::Clear,
        Request::Wait {
            key: "k".into(),
            timeout_ms: 1500,
            take: true,
        },
        Request::WaitAny {
            keys: vec!["a".into(), "b".into(), "c".into()],
            timeout_ms: 10,
            take: false,
        },
        Request::SubAdd {
            tag: 7,
            key: "k".into(),
        },
        Request::SubRemove { tag: 7 },
        Request::SubWait { timeout_ms: 250 },
        Request::Bye,
        Request::ShmOpen {
            path: "/dev/shm/relexi-test".into(),
            ring_bytes: 1 << 20,
        },
        Request::PutMany { items: vec![] },
        Request::PutMany {
            items: vec![
                ("m:0".into(), Value::tensor(vec![3], vec![1.0, -0.0, 2.5])),
                ("".into(), Value::Flag(false)),
                ("m:2".into(), Value::bytes(vec![255, 0, 7])),
            ],
        },
        Request::TakeMany {
            keys: vec![],
            timeout_ms: 0,
        },
        Request::TakeMany {
            keys: vec!["a".into(), "b".into()],
            timeout_ms: u64::MAX,
        },
        Request::SubWaitMany {
            timeout_ms: 250,
            max: u32::MAX,
        },
    ]
}

fn sample_responses() -> Vec<Response> {
    vec![
        Response::Unit,
        Response::Bool(true),
        Response::Bool(false),
        Response::Maybe(None),
        Response::Maybe(Some(Value::Scalar(-0.0))),
        Response::Maybe(Some(Value::Flag(true))),
        Response::Hit(None),
        Response::Hit(Some((9, Value::tensor(vec![4], vec![1.0, 2.0, 3.0, 4.0])))),
        Response::Many(vec![]),
        Response::Many(vec![
            (0, Value::Scalar(1.5)),
            (u64::MAX, Value::tensor(vec![2], vec![-1.0, f32::MAX])),
        ]),
        Response::Error("boom".into()),
    ]
}

#[test]
fn codec_never_panics_on_random_garbage() {
    // Deterministic byte soup: every decoder must return Ok or Err on
    // arbitrary input — never panic, never blow up an allocation (the
    // wire layer validates declared lengths against the buffer first).
    let mut rng = Rng::new(0xF0CC);
    for _ in 0..20_000 {
        let len = rng.below(96);
        let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = Request::decode(&buf);
        let _ = Response::decode(&buf);
        let mut pos = 0usize;
        let _ = Value::decode_from(&buf, &mut pos);
        let n = buf.len().min(4);
        let mut hdr = [0u8; 4];
        hdr[..n].copy_from_slice(&buf[..n]);
        let _ = frame_len(hdr);
    }
}

#[test]
fn codec_truncation_errors_or_stays_consistent() {
    // Every strict prefix of a valid encoding either errors (the normal
    // case: the payload runs out) or — if it happens to be a complete
    // message — re-encodes to exactly those bytes.  Either way: no
    // panic, no silent misparse.
    for req in sample_requests() {
        let mut buf = Vec::new();
        req.encode_into(&mut buf);
        let full = Request::decode(&buf).expect("round trip");
        assert_eq!(full, req);
        for k in 0..buf.len() {
            match Request::decode(&buf[..k]) {
                Err(_) => {}
                Ok(d) => {
                    let mut re = Vec::new();
                    d.encode_into(&mut re);
                    assert_eq!(re, &buf[..k], "prefix decode of {req:?} inconsistent");
                }
            }
        }
    }
    for resp in sample_responses() {
        let mut buf = Vec::new();
        resp.encode_into(&mut buf);
        let full = Response::decode(&buf).expect("round trip");
        assert_eq!(full, resp);
        for k in 0..buf.len() {
            match Response::decode(&buf[..k]) {
                Err(_) => {}
                Ok(d) => {
                    let mut re = Vec::new();
                    d.encode_into(&mut re);
                    assert_eq!(re, &buf[..k], "prefix decode of {resp:?} inconsistent");
                }
            }
        }
    }
}

#[test]
fn codec_survives_single_byte_mutations() {
    // Flip every byte of every valid encoding through a handful of
    // deterministic xor masks: decoding must never panic, and when it
    // succeeds the result must re-encode to the mutated bytes.
    for req in sample_requests() {
        let mut buf = Vec::new();
        req.encode_into(&mut buf);
        for i in 0..buf.len() {
            for mask in [0x01u8, 0x80, 0xFF] {
                let mut m = buf.clone();
                m[i] ^= mask;
                if let Ok(d) = Request::decode(&m) {
                    let mut re = Vec::new();
                    d.encode_into(&mut re);
                    assert_eq!(re, m, "mutated decode of {req:?} inconsistent");
                }
            }
        }
    }
    for resp in sample_responses() {
        let mut buf = Vec::new();
        resp.encode_into(&mut buf);
        for i in 0..buf.len() {
            for mask in [0x01u8, 0x80, 0xFF] {
                let mut m = buf.clone();
                m[i] ^= mask;
                let _ = Response::decode(&m);
            }
        }
    }
}

#[test]
fn frame_length_bounds_are_enforced() {
    assert_eq!(frame_len(64u32.to_le_bytes()).unwrap(), 64);
    assert_eq!(frame_len((MAX_FRAME as u32).to_le_bytes()).unwrap(), MAX_FRAME);
    assert!(frame_len((MAX_FRAME as u32 + 1).to_le_bytes()).is_err());
    assert!(frame_len(u32::MAX.to_le_bytes()).is_err());
}

// --------------------------------------------------------- conformance

/// The store contract every transport must serve identically.  Ends
/// with a put/clear race, so run it last against a given store.
fn conformance(t: &Arc<dyn Transport>) {
    // Basics: put / get / exists / take-consumes / delete.
    t.put("c:a", Value::Scalar(2.5)).unwrap();
    assert!(t.exists("c:a").unwrap());
    match t.get("c:a").unwrap() {
        Some(Value::Scalar(x)) => assert_eq!(x, 2.5),
        v => panic!("get c:a -> {v:?}"),
    }
    assert!(t.get("c:missing").unwrap().is_none());
    assert!(t.take("c:a").unwrap().is_some());
    assert!(t.take("c:a").unwrap().is_none(), "take must consume");
    t.put("c:b", Value::Flag(true)).unwrap();
    assert!(t.delete("c:b").unwrap());
    assert!(!t.delete("c:b").unwrap());

    // Tensor fidelity across the wire, bit for bit.
    let odd = vec![f32::MIN_POSITIVE, -0.0, 1.0e-38, 3.5, -7.25, f32::MAX];
    t.put("c:t", Value::tensor(vec![2, 3], odd.clone())).unwrap();
    let (shape, data) = match t.get("c:t").unwrap() {
        Some(v) => {
            let (s, d) = v.as_tensor().map(|(s, d)| (s.to_vec(), d.to_vec())).unwrap();
            (s, d)
        }
        None => panic!("tensor lost"),
    };
    assert_eq!(shape, vec![2, 3]);
    for (a, b) in odd.iter().zip(&data) {
        assert_eq!(a.to_bits(), b.to_bits(), "tensor payload altered in flight");
    }

    // Exactly-once wait_take: racing waiters split the values, every
    // value delivered to exactly one of them.
    const N_VALUES: usize = 16;
    let keys: Vec<String> = (0..N_VALUES).map(|i| format!("c:race:{i}")).collect();
    let hits: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let waiters: Vec<_> = (0..3)
        .map(|w| {
            let t = t.clone();
            let keys = keys.clone();
            let hits = hits.clone();
            std::thread::Builder::new()
                .name(format!("conf-waiter-{w}"))
                .spawn(move || loop {
                    let refs: Vec<&str> = keys.iter().map(|k| k.as_str()).collect();
                    match t.wait_any(&refs, Duration::from_millis(500), true).unwrap() {
                        Some((i, _)) => hits.lock().unwrap().push(i),
                        None => return, // quiet for 500 ms: producer done
                    }
                })
                .unwrap()
        })
        .collect();
    for (i, k) in keys.iter().enumerate() {
        t.put(k, Value::Scalar(i as f64)).unwrap();
    }
    for h in waiters {
        h.join().unwrap();
    }
    let mut seen = hits.lock().unwrap().clone();
    seen.sort_unstable();
    assert_eq!(
        seen,
        (0..N_VALUES).collect::<Vec<_>>(),
        "each value must be delivered exactly once"
    );

    // Batched puts/takes (PR-9): one logical op covers many keys, with
    // per-key visibility identical to the per-key loop, hits ascending.
    t.put_many(vec![
        ("c:m:0".into(), Value::Scalar(0.5)),
        ("c:m:1".into(), Value::tensor(vec![2], vec![1.0, -2.0])),
        ("c:m:2".into(), Value::Flag(true)),
    ])
    .unwrap();
    assert!(t.exists("c:m:1").unwrap(), "put_many key visible per-key");
    let hits = t
        .take_many(&["c:m:0", "c:m:miss", "c:m:1", "c:m:2"], Duration::from_secs(5))
        .unwrap();
    assert_eq!(
        hits.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
        vec![0, 2, 3],
        "take_many returns present keys ascending"
    );
    match &hits[0].1 {
        Value::Scalar(x) => assert_eq!(*x, 0.5),
        v => panic!("take_many value altered: {v:?}"),
    }
    assert!(t.get("c:m:0").unwrap().is_none(), "take_many consumes");
    assert!(
        t.take_many(&["c:m:0", "c:m:1"], Duration::from_millis(50))
            .unwrap()
            .is_empty(),
        "empty take_many is a timeout, not a hit"
    );
    // A blocked take_many must wake on a later put.
    let waker = {
        let t = t.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            t.put_many(vec![("c:m:late".into(), Value::Scalar(9.0))]).unwrap();
        })
    };
    let late = t.take_many(&["c:m:late"], Duration::from_secs(10)).unwrap();
    assert_eq!(late.len(), 1, "take_many wakes on a late batched put");
    waker.join().unwrap();

    // Exactly-once take_many under racing consumers: two threads race
    // batched takes over one key set; every value must land in exactly
    // one of them.
    const N_BATCH: usize = 12;
    let bkeys: Vec<String> = (0..N_BATCH).map(|i| format!("c:mrace:{i}")).collect();
    let bhits: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let takers: Vec<_> = (0..2)
        .map(|w| {
            let t = t.clone();
            let keys = bkeys.clone();
            let hits = bhits.clone();
            std::thread::Builder::new()
                .name(format!("conf-taker-{w}"))
                .spawn(move || loop {
                    let refs: Vec<&str> = keys.iter().map(|k| k.as_str()).collect();
                    let got = t.take_many(&refs, Duration::from_millis(500)).unwrap();
                    if got.is_empty() {
                        return; // quiet for 500 ms: producer done
                    }
                    hits.lock().unwrap().extend(got.into_iter().map(|(i, _)| i));
                })
                .unwrap()
        })
        .collect();
    for (i, k) in bkeys.iter().enumerate() {
        t.put(k, Value::Scalar(i as f64)).unwrap();
    }
    for h in takers {
        h.join().unwrap();
    }
    let mut seen = bhits.lock().unwrap().clone();
    seen.sort_unstable();
    assert_eq!(
        seen,
        (0..N_BATCH).collect::<Vec<_>>(),
        "each value must be taken by exactly one batched taker"
    );

    // Subscription add/remove deltas: only registered tags fire, a
    // removed tag never fires, delivery retires the registration.
    let mut sub = t.subscribe().unwrap();
    sub.add(7, "c:s:a").unwrap();
    sub.add(9, "c:s:b").unwrap();
    assert_eq!(sub.len(), 2);
    t.put("c:s:b", Value::Flag(true)).unwrap();
    match sub.wait_take(Duration::from_secs(5)).unwrap() {
        Some((9, Value::Flag(true))) => {}
        other => panic!("subscription delivered {other:?}"),
    }
    assert_eq!(sub.len(), 1, "delivery retires the registration");
    sub.remove(7).unwrap();
    t.put("c:s:a", Value::Flag(true)).unwrap();
    assert!(
        sub.wait_take(Duration::from_millis(300)).unwrap().is_none(),
        "removed tag must never fire"
    );
    sub.add(1, "c:s:c").unwrap();
    t.put("c:s:c", Value::Scalar(4.0)).unwrap();
    match sub.wait_take(Duration::from_secs(5)).unwrap() {
        Some((1, Value::Scalar(x))) => assert_eq!(x, 4.0),
        other => panic!("re-added subscription delivered {other:?}"),
    }

    // Batched subscription drain: a wave of puts comes back through
    // wait_take_many, each delivery exactly once, max respected.
    sub.add(20, "c:sm:a").unwrap();
    sub.add(21, "c:sm:b").unwrap();
    sub.add(22, "c:sm:c").unwrap();
    t.put_many(vec![
        ("c:sm:a".into(), Value::Scalar(1.0)),
        ("c:sm:b".into(), Value::Scalar(2.0)),
        ("c:sm:c".into(), Value::Scalar(3.0)),
    ])
    .unwrap();
    let mut tags: Vec<usize> = Vec::new();
    while tags.len() < 3 {
        let got = sub.wait_take_many(Duration::from_secs(5), 2).unwrap();
        assert!(!got.is_empty(), "subscribed wave must be delivered");
        assert!(got.len() <= 2, "wait_take_many must honor max");
        tags.extend(got.into_iter().map(|(tag, _)| tag));
    }
    tags.sort_unstable();
    assert_eq!(tags, vec![20, 21, 22], "each delivery exactly once");
    assert!(
        sub.wait_take_many(Duration::from_millis(200), 4).unwrap().is_empty(),
        "drained subscription has nothing left"
    );

    // Exactly-once wait_take_many under RACING subscriptions: two
    // independent subscriptions register the same keys; the store wakes
    // both, but the authoritative take must hand each value to exactly
    // one of them.
    const N_SUBRACE: usize = 10;
    let srhits: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let racers: Vec<_> = (0..2)
        .map(|w| {
            let t = t.clone();
            let hits = srhits.clone();
            std::thread::Builder::new()
                .name(format!("conf-subracer-{w}"))
                .spawn(move || {
                    let mut sub = t.subscribe().unwrap();
                    for i in 0..N_SUBRACE {
                        sub.add(i, &format!("c:sr:{i}")).unwrap();
                    }
                    loop {
                        let got = sub.wait_take_many(Duration::from_millis(500), N_SUBRACE).unwrap();
                        if got.is_empty() {
                            return; // quiet for 500 ms: producer done
                        }
                        hits.lock().unwrap().extend(got.into_iter().map(|(tag, _)| tag));
                    }
                })
                .unwrap()
        })
        .collect();
    t.put_many(
        (0..N_SUBRACE)
            .map(|i| (format!("c:sr:{i}"), Value::Scalar(i as f64)))
            .collect(),
    )
    .unwrap();
    for h in racers {
        h.join().unwrap();
    }
    let mut seen = srhits.lock().unwrap().clone();
    seen.sort_unstable();
    assert_eq!(
        seen,
        (0..N_SUBRACE).collect::<Vec<_>>(),
        "racing batched subscriptions must split the wave exactly once"
    );

    // put/clear race: concurrent writers against repeated clears must
    // neither panic nor wedge, and a final clear leaves nothing behind.
    let writer = {
        let t = t.clone();
        std::thread::spawn(move || {
            for i in 0..200 {
                t.put(&format!("c:pc:{}", i % 8), Value::Scalar(i as f64))
                    .unwrap();
            }
        })
    };
    for _ in 0..50 {
        t.clear().unwrap();
    }
    writer.join().unwrap();
    t.clear().unwrap();
    for i in 0..8 {
        assert!(t.get(&format!("c:pc:{i}")).unwrap().is_none(), "clear missed a key");
    }
}

#[test]
fn conformance_inproc() {
    let orch = Orchestrator::launch(4);
    let t: Arc<dyn Transport> = Arc::new(InprocTransport::new(orch.store().clone()));
    assert_eq!(t.kind(), "inproc");
    conformance(&t);
}

#[test]
fn conformance_tcp() {
    let orch = Orchestrator::launch(4);
    let server = orch.serve("127.0.0.1:0").unwrap();
    let t: Arc<dyn Transport> =
        RemoteTransport::connect("tcp", &server.addr().to_string(), 3).unwrap();
    assert_eq!(t.kind(), "tcp");
    conformance(&t);
}

#[cfg(unix)]
#[test]
fn conformance_shm() {
    let orch = Orchestrator::launch(4);
    let server = orch.serve("127.0.0.1:0").unwrap();
    let t: Arc<dyn Transport> =
        RemoteTransport::connect("shm", &server.addr().to_string(), 3).unwrap();
    assert_eq!(t.kind(), "shm");
    conformance(&t);
}

// ------------------------------------------------- loopback-TCP smoke

/// 8-env Burgers config with two scenario variants — small enough for
/// CI, heterogeneous enough to exercise early-done bookkeeping across
/// the process boundary.
fn burgers8_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.rl.backend = "burgers".to_string();
    cfg.burgers = BurgersConfig {
        points: 48,
        segments: 4,
        k_max: 6,
        t_end: 0.5, // 5 actions at the base horizon
        truth_states: 4,
        truth_spinup: 1.0,
        truth_interval: 0.25,
        ..BurgersConfig::default()
    };
    cfg.rl.n_envs = 8;
    cfg.rl.split_init_pool = true;
    cfg.rl.variants = vec![
        EnvVariant::default(),
        EnvVariant {
            name: "short".into(),
            t_end_scale: 0.6, // 3 actions: early-done across processes
            ..EnvVariant::default()
        },
    ];
    cfg
}

fn assert_episodes_identical(a: &[Episode], b: &[Episode]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.variant, y.variant, "env {i} variant");
        assert_eq!(x.steps.len(), y.steps.len(), "env {i} episode length");
        for (t, (sx, sy)) in x.steps.iter().zip(&y.steps).enumerate() {
            assert_eq!(sx.obs, sy.obs, "env {i} step {t} obs");
            assert_eq!(sx.act, sy.act, "env {i} step {t} act");
            assert_eq!(sx.logp, sy.logp, "env {i} step {t} logp");
            assert_eq!(sx.value, sy.value, "env {i} step {t} value");
            assert_eq!(
                sx.reward.to_bits(),
                sy.reward.to_bits(),
                "env {i} step {t} reward"
            );
        }
    }
}

/// Two sampling iterations (construction wave + steady-state wave) on a
/// freshly built pool, returning both full rollouts (episodes plus the
/// supervision report the chaos tests inspect) and the trainer store's
/// cumulative counters (`frames` / `batched_keys` — the PR-9 wire-shape
/// invariant).
fn two_iterations_with_stats(cfg: RunConfig, seed: u64) -> (Rollouts, Rollouts, StatsSnapshot) {
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::from_config(cfg, None, &orch).unwrap();
    let mut rng = Rng::new(seed);
    let r0 = pool
        .collect_with(&orch, &Protocol::new("lb0"), stub_policy, &mut rng, false, n_envs)
        .unwrap();
    orch.clear();
    let r1 = pool
        .collect_with(&orch, &Protocol::new("lb1"), stub_policy, &mut rng, false, n_envs)
        .unwrap();
    orch.clear();
    let stats = orch.store().stats();
    (r0, r1, stats)
}

fn two_iterations_rollouts(cfg: RunConfig, seed: u64) -> (Rollouts, Rollouts) {
    let (r0, r1, _) = two_iterations_with_stats(cfg, seed);
    (r0, r1)
}

fn two_iterations(cfg: RunConfig, seed: u64) -> (Vec<Episode>, Vec<Episode>) {
    let (r0, r1) = two_iterations_rollouts(cfg, seed);
    (r0.episodes, r1.episodes)
}

/// `burgers8_cfg` wired to real env-worker processes over loopback TCP,
/// with a tight heartbeat so the chaos tests detect faults quickly.
fn burgers8_procs_cfg() -> RunConfig {
    let mut cfg = burgers8_cfg();
    cfg.orchestrator.workers = "processes".to_string();
    cfg.orchestrator.transport = "tcp".to_string();
    cfg.orchestrator.env_procs = 2; // 2 workers x 4 envs
    cfg.orchestrator.worker_bin = env!("CARGO_BIN_EXE_relexi").to_string();
    cfg.orchestrator.heartbeat_period_ms = 200;
    cfg.orchestrator.heartbeat_expiry_ms = 2000;
    cfg
}

#[test]
fn tcp_loopback_worker_processes_match_inproc_bitwise() {
    // The PR-7 acceptance smoke (run explicitly by the CI loopback job):
    // the same 8-env Burgers iteration, once with in-process env threads
    // over the inproc transport, once with real `relexi env-worker` OS
    // processes dialing the loopback-TCP exchange — same seed, and every
    // observation, action, log-prob, value and reward bit-identical.
    // Since PR-9 the processes leg runs the wave-coalesced batched
    // exchange by default, so this is also the batched bit-identity gate.
    let (inproc0, inproc1) = two_iterations(burgers8_cfg(), 41);
    let (tcp0, tcp1) = two_iterations(burgers8_procs_cfg(), 41);

    assert_episodes_identical(&inproc0, &tcp0);
    assert_episodes_identical(&inproc1, &tcp1);
    // Pool drop on the processes side must have reaped its workers; the
    // bounded-teardown test below covers the trainer-death path.
}

#[test]
fn tcp_loopback_batched_and_perkey_legs_match_and_coalesce_frames() {
    // PR-9 acceptance: both `batch_ops` legs of the loopback-TCP pool
    // reproduce the in-process episodes bitwise at the same seed, and
    // the exchange's frame counters prove the wire-shape claim — the
    // batched leg moves the same waves in a small fraction of the data
    // frames (O(W·T) vs O(E·T·ops)) and is the only leg with batched
    // keys on the wire.
    let (in0, in1) = two_iterations(burgers8_cfg(), 53);

    let batched_cfg = burgers8_procs_cfg(); // batch_ops defaults on
    assert!(batched_cfg.orchestrator.batch_ops);
    let (b0, b1, bstats) = two_iterations_with_stats(batched_cfg, 53);

    let mut perkey_cfg = burgers8_procs_cfg();
    perkey_cfg.orchestrator.batch_ops = false;
    let (p0, p1, pstats) = two_iterations_with_stats(perkey_cfg, 53);

    assert_episodes_identical(&in0, &b0.episodes);
    assert_episodes_identical(&in1, &b1.episodes);
    assert_episodes_identical(&in0, &p0.episodes);
    assert_episodes_identical(&in1, &p1.episodes);

    assert_eq!(
        pstats.batched_keys, 0,
        "per-key leg must not touch the batched path"
    );
    assert!(
        bstats.batched_keys > 0,
        "batched leg must move its waves through put_many/take_many"
    );
    assert!(
        bstats.frames > 0,
        "remote exchange must count data frames"
    );
    assert!(
        bstats.frames * 2 < pstats.frames,
        "wave coalescing must cut data frames at least in half \
         (batched {} vs per-key {})",
        bstats.frames,
        pstats.frames
    );
}

// ------------------------------------------------------------- chaos

#[test]
fn chaos_killed_worker_recovers_bit_identical() {
    // PR-8 acceptance: `killput:w0@25` makes worker 0's transport abort
    // the whole process mid-wave (its block has published some — not all
    // — of its states and rewards).  The supervisor must notice the
    // child exit within a heartbeat slice, respawn a generation-1
    // worker, replay the recorded action prefix, and finish BOTH waves
    // bit-identical to the fault-free in-process run at the same seed.
    // Since PR-9 the worker runs the batched exchange by default, and
    // the put counter ticks per LOGICAL put inside `put_many` — so the
    // kill lands mid-batch and the block's ENTIRE in-flight batch frame
    // is lost, the batched equivalent of losing one per-key put.
    let (inproc0, inproc1) = two_iterations(burgers8_cfg(), 43);

    let mut cfg = burgers8_procs_cfg();
    cfg.fault.plan = "killput:w0@25".to_string();
    cfg.fault.max_respawns = 2;
    let (r0, r1) = two_iterations_rollouts(cfg, 43);

    let total_respawns = r0.supervision.respawns + r1.supervision.respawns;
    assert!(
        total_respawns >= 1,
        "fault plan should have killed worker 0 at least once (reports: {:?} / {:?})",
        r0.supervision,
        r1.supervision
    );
    assert!(r0.supervision.dropped_envs.is_empty(), "no block may be dropped");
    assert!(r1.supervision.dropped_envs.is_empty(), "no block may be dropped");
    assert_episodes_identical(&inproc0, &r0.episodes);
    assert_episodes_identical(&inproc1, &r1.episodes);
}

#[test]
fn chaos_killed_worker_recovers_bit_identical_perkey() {
    // The same mid-wave kill with `batch_ops = off`: the A/B baseline
    // path must keep the PR-8 fault-tolerance guarantees it always had.
    let (inproc0, inproc1) = two_iterations(burgers8_cfg(), 43);

    let mut cfg = burgers8_procs_cfg();
    cfg.orchestrator.batch_ops = false;
    cfg.fault.plan = "killput:w0@25".to_string();
    cfg.fault.max_respawns = 2;
    let (r0, r1) = two_iterations_rollouts(cfg, 43);

    assert!(
        r0.supervision.respawns + r1.supervision.respawns >= 1,
        "fault plan should have killed worker 0 at least once"
    );
    assert!(r0.supervision.dropped_envs.is_empty(), "no block may be dropped");
    assert!(r1.supervision.dropped_envs.is_empty(), "no block may be dropped");
    assert_episodes_identical(&inproc0, &r0.episodes);
    assert_episodes_identical(&inproc1, &r1.episodes);
}

#[test]
fn worker_killed_before_first_begin_recovers_bit_identical() {
    // Teardown race: `kill:w0@0` exits worker 0 the moment it SEES its
    // first begin command — after hello, before taking the message or
    // publishing a single state.  The supervisor must clear the untaken
    // begin, respawn, and replay the whole block from recorded seeds.
    let (inproc0, inproc1) = two_iterations(burgers8_cfg(), 47);

    let mut cfg = burgers8_procs_cfg();
    cfg.fault.plan = "kill:w0@0".to_string();
    let (r0, r1) = two_iterations_rollouts(cfg, 47);

    assert_eq!(r0.supervision.respawns, 1, "exactly one respawn in wave 0");
    assert!(r0.supervision.dropped_envs.is_empty());
    assert!(
        r1.supervision.clean(),
        "generation 1 carries no fault directive: {:?}",
        r1.supervision
    );
    assert_episodes_identical(&inproc0, &r0.episodes);
    assert_episodes_identical(&inproc1, &r1.episodes);
}

#[test]
fn max_respawns_exhaustion_degrades_to_short_wave() {
    // PR-8 acceptance: `kill:w0@0*` fires at every generation, so the
    // replacement dies exactly like its predecessor.  With a respawn
    // budget of 1 the supervisor must give up on the block, complete
    // the wave short (4 of 8 envs) WITHOUT an error, and keep serving
    // degraded waves afterwards.
    let mut cfg = burgers8_procs_cfg();
    cfg.fault.plan = "kill:w0@0*".to_string();
    cfg.fault.max_respawns = 1;

    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::from_config(cfg, None, &orch).unwrap();
    let mut rng = Rng::new(7);
    let r0 = pool
        .collect_with(&orch, &Protocol::new("deg0"), stub_policy, &mut rng, false, n_envs)
        .unwrap();
    assert_eq!(r0.supervision.respawns, 1, "budget of 1 respawn spent");
    assert_eq!(r0.supervision.dropped_envs, vec![0, 1, 2, 3]);
    assert_eq!(r0.episodes.len(), 4, "surviving block's episodes only");
    for (i, ep) in r0.episodes.iter().enumerate() {
        assert!(!ep.steps.is_empty(), "surviving episode {i} must have steps");
    }

    // The degraded pool keeps working: the dropped block stays dropped
    // (no further respawn attempts), the rest completes normally.
    orch.clear();
    let r1 = pool
        .collect_with(&orch, &Protocol::new("deg1"), stub_policy, &mut rng, false, n_envs)
        .unwrap();
    assert_eq!(r1.supervision.respawns, 0, "dropped block is not retried");
    assert_eq!(r1.supervision.dropped_envs, vec![0, 1, 2, 3]);
    assert_eq!(r1.episodes.len(), 4);
    orch.clear();
}

// ----------------------------------------------------- telemetry smoke

#[test]
fn tcp_loopback_telemetry_merged_trace_is_valid_and_bit_identical() {
    // PR-10 acceptance: a full `relexi train` over loopback-TCP worker
    // processes with `[telemetry] enabled = true` must (a) train
    // bit-identically to the telemetry-off run at the same seed, and
    // (b) emit ONE merged Chrome-trace JSON spanning the trainer and
    // both env-worker processes — valid JSON, events globally sorted by
    // timestamp, spans properly nested per (pid, tid), and the frame
    // instant-events equal to the exchange's `StoreStats.frames`.
    // Runs in child processes so the process-wide telemetry switch
    // cannot interact with concurrently running tests.
    use relexi::util::binio::Json;
    use std::collections::{HashMap, HashSet};
    use std::path::PathBuf;

    let dir = std::env::temp_dir().join(format!("relexi_telemetry_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let run_train = |telemetry: bool, sub: &str| -> PathBuf {
        let work = dir.join(sub);
        std::fs::create_dir_all(&work).unwrap();
        let mut cfg = burgers8_procs_cfg();
        cfg.runtime.backend = "native".to_string();
        cfg.rl.iterations = 2;
        cfg.rl.eval_every = 0;
        cfg.rl.minibatch = 32;
        cfg.out_dir = work.join("out").to_string_lossy().into_owned();
        cfg.telemetry.enabled = telemetry;
        cfg.telemetry.log_level = "warn".to_string();
        let cfg_path = work.join("config.toml");
        std::fs::write(&cfg_path, cfg.to_toml_string()).unwrap();
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_relexi"))
            .arg("train")
            .arg("--config")
            .arg(&cfg_path)
            .current_dir(&work)
            .output()
            .expect("spawn relexi train");
        assert!(
            out.status.success(),
            "train (telemetry={telemetry}) failed:\nstdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        work
    };

    let off = run_train(false, "off");
    let on = run_train(true, "on");

    // (a) Telemetry must not perturb the run: bit-identical final
    // checkpoint, and identical deterministic CSV columns (the trailing
    // exchange_p50/p99/frames columns legitimately differ).
    let ck_off = std::fs::read(off.join("out/policy_final.bin")).unwrap();
    let ck_on = std::fs::read(on.join("out/policy_final.bin")).unwrap();
    assert_eq!(ck_off, ck_on, "telemetry-on training must be bit-identical");
    let csv_off = std::fs::read_to_string(off.join("out/training.csv")).unwrap();
    let csv_on = std::fs::read_to_string(on.join("out/training.csv")).unwrap();
    assert_eq!(csv_off.lines().count(), csv_on.lines().count());
    // Deterministic columns only: returns and PPO diagnostics (the
    // *_time_s columns are wall clock, and the trailing exchange columns
    // are the telemetry deltas themselves).
    let det = [0usize, 1, 2, 3, 4, 9, 10, 11];
    for (a, b) in csv_off.lines().zip(csv_on.lines()) {
        let ca: Vec<&str> = a.split(',').collect();
        let cb: Vec<&str> = b.split(',').collect();
        for &i in &det {
            assert_eq!(ca[i], cb[i], "deterministic CSV column {i} must match");
        }
    }

    // (b) Exactly one merged trace + one aggregate, only in the
    // telemetry-on run's working directory.
    let find = |work: &PathBuf, prefix: &str| -> Vec<PathBuf> {
        std::fs::read_dir(work)
            .unwrap()
            .filter_map(|e| {
                let p = e.unwrap().path();
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(prefix) && n.ends_with(".json"))
                    .then_some(p)
            })
            .collect()
    };
    assert!(find(&off, "TRACE_").is_empty(), "telemetry-off run must not trace");
    assert!(find(&off, "TELEMETRY_").is_empty());
    let traces = find(&on, "TRACE_");
    let tels = find(&on, "TELEMETRY_");
    assert_eq!(traces.len(), 1, "exactly one merged trace: {traces:?}");
    assert_eq!(tels.len(), 1, "exactly one telemetry aggregate: {tels:?}");

    let trace =
        Json::parse(&std::fs::read_to_string(&traces[0]).unwrap()).expect("trace is valid JSON");
    let events = trace.arr().expect("chrome trace is a JSON array");
    assert!(!events.is_empty());

    // Process coverage: trainer + both env-worker processes in ONE file.
    let mut procs: HashSet<String> = HashSet::new();
    for e in events {
        if e.get("ph").unwrap().str().unwrap() == "M"
            && e.get("name").unwrap().str().unwrap() == "process_name"
        {
            procs.insert(e.get("args").unwrap().get("name").unwrap().str().unwrap().to_string());
        }
    }
    for want in ["trainer", "w0", "w1"] {
        assert!(procs.contains(want), "trace must span {want}: got {procs:?}");
    }

    // Global timestamp order, per-(pid,tid) span nesting, frame events.
    let mut last_ts = f64::MIN;
    let mut spans_by_thread: HashMap<(i64, i64), Vec<(f64, f64, String)>> = HashMap::new();
    let mut frame_events = 0u64;
    let mut span_names: HashSet<String> = HashSet::new();
    for e in events {
        let ph = e.get("ph").unwrap().str().unwrap();
        if ph == "M" {
            continue;
        }
        let ts = e.get("ts").unwrap().num().unwrap();
        assert!(ts >= last_ts, "trace events must be globally sorted by ts");
        last_ts = ts;
        let pid = e.get("pid").unwrap().num().unwrap() as i64;
        let tid = e.get("tid").unwrap().num().unwrap() as i64;
        let name = e.get("name").unwrap().str().unwrap();
        match ph {
            "X" => {
                let dur = e.get("dur").unwrap().num().unwrap();
                assert!(dur >= 0.0);
                span_names.insert(name.to_string());
                spans_by_thread
                    .entry((pid, tid))
                    .or_default()
                    .push((ts, dur, name.to_string()));
            }
            "i" => {
                if name.starts_with("frame.") {
                    frame_events += 1;
                }
            }
            "C" => {}
            other => panic!("unexpected trace phase {other:?}"),
        }
    }
    // Spans on one thread come from nested RAII guards, so as intervals
    // they must strictly nest (never partially overlap).  Sort by
    // (start asc, duration desc) — at equal starts the enclosing span
    // comes first — and stack-check the intervals.
    for ((pid, tid), mut spans) in spans_by_thread {
        spans.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut stack: Vec<f64> = Vec::new();
        for (ts, dur, name) in spans {
            while stack.last().is_some_and(|&end| end <= ts) {
                stack.pop();
            }
            if let Some(&end) = stack.last() {
                assert!(
                    ts + dur <= end,
                    "span {name} [{ts}, {}] on {pid}/{tid} escapes its enclosing span (ends {end})",
                    ts + dur
                );
            }
            stack.push(ts + dur);
        }
    }
    for want in ["wave.collect", "wave.policy", "train.minibatch", "burgers.wave"] {
        assert!(span_names.contains(want), "missing span {want}: {span_names:?}");
    }

    let tel =
        Json::parse(&std::fs::read_to_string(&tels[0]).unwrap()).expect("aggregate is valid JSON");
    assert!(tel.get("processes").unwrap().num().unwrap() >= 3.0);
    let frames = tel.get("store").unwrap().get("frames").unwrap().num().unwrap() as u64;
    assert!(frames > 0, "remote exchange must have counted data frames");
    assert_eq!(
        frame_events, frames,
        "frame instant-events in the merged trace must equal StoreStats.frames"
    );
    // The aggregate folds in the satellite counter sections.
    for section in ["pool", "supervision", "batch"] {
        tel.get(section).unwrap_or_else(|_| panic!("aggregate missing section {section:?}"));
    }

    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- worker teardown

#[test]
fn env_worker_exits_when_trainer_dies() {
    // Satellite 6: an env-worker whose exchange disappears WITHOUT the
    // stop flag (trainer crash) must exit on its own — bounded
    // reconnect, then clean shutdown — not linger as an orphan.
    let mut cfg = burgers8_cfg();
    cfg.rl.n_envs = 2;
    cfg.orchestrator.workers = "processes".to_string();
    cfg.orchestrator.transport = "tcp".to_string();

    let orch = Orchestrator::launch(2);
    let server = orch.serve("127.0.0.1:0").unwrap();
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_relexi"))
        .arg("env-worker")
        .arg("--connect")
        .arg(server.addr().to_string())
        .arg("--transport")
        .arg("tcp")
        .arg("--worker-id")
        .arg("0")
        .arg("--env-start")
        .arg("0")
        .arg("--env-count")
        .arg("2")
        .env("RELEXI_WORKER_CONFIG", cfg.to_toml_string())
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn env-worker");

    // The worker announces itself once its envs are built.
    let client = orch.client();
    let hello_deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if client
            .poll(ctl_hello_key(0).as_str(), Duration::from_millis(200))
            .is_some()
        {
            break;
        }
        assert!(
            Instant::now() < hello_deadline,
            "env-worker never said hello"
        );
    }

    // Kill the trainer side: the exchange (and every connection) dies
    // with no stop flag ever posted.
    drop(server);

    let exit_deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait().expect("try_wait") {
            Some(status) => {
                assert!(
                    status.success(),
                    "worker should exit cleanly after trainer death, got {status:?}"
                );
                break;
            }
            None => {
                if Instant::now() >= exit_deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    panic!("env-worker still alive 30 s after trainer death");
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

#[test]
fn env_worker_exits_when_trainer_dies_mid_episode() {
    // Teardown race: the trainer dies while the worker's env threads are
    // BLOCKED mid-episode waiting for actions that will never arrive.
    // The dead transport must unblock those waits within the reconnect
    // bound and the process must exit — no orphan pinned on a 600 s
    // poll timeout.  (Exit status is not asserted: the env threads may
    // legitimately unwind on the dead exchange; the guarantee is a
    // bounded exit.)
    let mut cfg = burgers8_cfg();
    cfg.rl.n_envs = 2;
    cfg.orchestrator.workers = "processes".to_string();
    cfg.orchestrator.transport = "tcp".to_string();

    let orch = Orchestrator::launch(2);
    let server = orch.serve("127.0.0.1:0").unwrap();
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_relexi"))
        .arg("env-worker")
        .arg("--connect")
        .arg(server.addr().to_string())
        .arg("--transport")
        .arg("tcp")
        .arg("--worker-id")
        .arg("0")
        .arg("--env-start")
        .arg("0")
        .arg("--env-count")
        .arg("2")
        .env("RELEXI_WORKER_CONFIG", cfg.to_toml_string())
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn env-worker");

    let client = orch.client();
    let hello_deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if client
            .poll(ctl_hello_key(0).as_str(), Duration::from_millis(200))
            .is_some()
        {
            break;
        }
        assert!(
            Instant::now() < hello_deadline,
            "env-worker never said hello"
        );
    }

    // Hand the worker a wave directly and wait until both env threads
    // have published their initial states — i.e. they are now blocked
    // polling for the step-0 actions we will never send.
    let proto = Protocol::new("inflight0");
    client.put_bytes(
        ctl_begin_key(0).as_str(),
        encode_begin(proto.run_tag(), &[(0, 1111), (1, 2222)]),
    );
    let state_deadline = Instant::now() + Duration::from_secs(60);
    for (env, n_actions) in [(0usize, 5usize), (1, 3)] {
        let key = proto.env_keys(env, n_actions).state[0].clone();
        loop {
            if client.poll(key.as_str(), Duration::from_millis(200)).is_some() {
                break;
            }
            assert!(
                Instant::now() < state_deadline,
                "env {env} never published its initial state"
            );
        }
    }

    // Kill the trainer side with the wave in flight.
    drop(server);

    let exit_deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait().expect("try_wait") {
            Some(_) => break,
            None => {
                if Instant::now() >= exit_deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    panic!("env-worker still alive 30 s after mid-episode trainer death");
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}
