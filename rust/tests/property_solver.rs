//! Property tests on solver invariants across randomized states and
//! parameters: conservation structure, projection, spectra, filtering,
//! and element gather consistency.

use relexi::fft::Cpx;
use relexi::solver::dns::{filter_to_les, pack_state, unpack_state};
use relexi::solver::init::random_solenoidal;
use relexi::solver::spectral::{divergence, kinetic_energy};
use relexi::solver::spectrum::energy_spectrum;
use relexi::solver::{ElementMap, Grid, Solver};
use relexi::util::Rng;

fn cases(n: usize, seed: u64) -> impl Iterator<Item = Rng> {
    (0..n).map(move |i| Rng::new(seed.wrapping_add(i as u64 * 77)))
}

#[test]
fn stepping_preserves_incompressibility() {
    for (i, mut rng) in cases(6, 1).enumerate() {
        let n = [8usize, 12, 16][i % 3];
        let mut s = Solver::new(n, 2, 0.01 + rng.uniform() * 0.02, 0.4);
        s.set_state(random_solenoidal(&s.grid, 0.5 + rng.uniform(), 3.0, &mut rng));
        if rng.uniform() > 0.5 {
            s.set_cs_uniform(rng.uniform() * 0.3);
        }
        s.advance(0.05 + rng.uniform() * 0.1);
        let mut div = s.grid.zeros();
        divergence(&s.grid, &s.uhat, &mut div);
        let max_div = div.iter().map(|c| c.norm_sq().sqrt()).fold(0.0, f64::max);
        let scale = kinetic_energy(&s.grid, &s.uhat).sqrt().max(1e-6)
            * (s.grid.len() as f64);
        assert!(max_div < 1e-8 * scale, "case {i}: div {max_div}");
    }
}

#[test]
fn unforced_viscous_flow_dissipates_monotonically() {
    for (i, mut rng) in cases(5, 2).enumerate() {
        let mut s = Solver::new(12, 2, 0.02 + rng.uniform() * 0.05, 0.4);
        s.set_state(random_solenoidal(&s.grid, 1.0, 3.0, &mut rng));
        let mut last = s.kinetic_energy();
        for _ in 0..4 {
            s.advance(0.05);
            let ke = s.kinetic_energy();
            assert!(ke < last * (1.0 + 1e-9), "case {i}: KE must not grow");
            last = ke;
        }
    }
}

#[test]
fn higher_cs_dissipates_at_least_as_much() {
    for (i, mut rng) in cases(4, 3).enumerate() {
        let grid = Grid::new(12);
        let state = random_solenoidal(&grid, 1.0, 3.0, &mut rng);
        let mut ke_by_cs = Vec::new();
        for cs in [0.0, 0.1, 0.3] {
            let mut s = Solver::new(12, 2, 0.01, 0.4);
            s.set_state(relexi::solver::spectral::clone_vec(&state));
            s.set_cs_uniform(cs);
            s.advance(0.15);
            ke_by_cs.push(s.kinetic_energy());
        }
        assert!(
            ke_by_cs[0] >= ke_by_cs[1] && ke_by_cs[1] >= ke_by_cs[2],
            "case {i}: KE should fall with Cs: {ke_by_cs:?}"
        );
    }
}

#[test]
fn spectrum_never_negative_and_sums_below_ke() {
    for mut rng in cases(20, 4) {
        let n = 8 + 4 * rng.below(3);
        let grid = Grid::new(n);
        let u = random_solenoidal(&grid, 0.1 + rng.uniform() * 2.0, 2.5, &mut rng);
        let spec = energy_spectrum(&grid, &u);
        assert!(spec.iter().all(|&e| e >= 0.0));
        let ke = kinetic_energy(&grid, &u);
        assert!(spec.iter().sum::<f64>() <= ke * (1.0 + 1e-9));
    }
}

#[test]
fn pack_unpack_is_identity_within_f32() {
    for mut rng in cases(20, 5) {
        let n = 6 + 2 * rng.below(5);
        let grid = Grid::new(n);
        let u = random_solenoidal(&grid, 1.0, 2.0, &mut rng);
        let back = unpack_state(&grid, &pack_state(&u));
        for c in 0..3 {
            for i in 0..grid.len() {
                let err = (u[c][i] - back[c][i]).norm_sq().sqrt();
                let mag = u[c][i].norm_sq().sqrt().max(1.0);
                assert!(err < 1e-5 * mag);
            }
        }
    }
}

#[test]
fn filtering_is_projection_idempotent_and_energy_decreasing() {
    for mut rng in cases(10, 6) {
        let nd = 16 + 8 * rng.below(2); // 16 or 24
        let nl = 8;
        let dns = Grid::new(nd);
        let les = Grid::new(nl);
        let u = random_solenoidal(&dns, 1.0, 3.0, &mut rng);
        let f1 = filter_to_les(&dns, &u, &les);
        // Idempotence: filtering the filtered field (same grid) = identity.
        let f2 = filter_to_les(&les, &f1, &les);
        for c in 0..3 {
            for i in 0..les.len() {
                assert!((f1[c][i] - f2[c][i]).norm_sq() < 1e-18);
            }
        }
        // Energy decreases under sharp truncation.
        assert!(kinetic_energy(&les, &f1) <= kinetic_energy(&dns, &u) + 1e-12);
    }
}

#[test]
fn observation_gather_matches_pointwise_lookup() {
    for mut rng in cases(10, 7) {
        let e = 2 + rng.below(2); // 2 or 3 elems/dir
        let p = 3 + rng.below(3); // 3..5 points/elem
        let n = e * p;
        let grid = Grid::new(n);
        let emap = ElementMap::new(&grid, e);
        let mut u = [grid.zeros(), grid.zeros(), grid.zeros()];
        for c in 0..3 {
            for v in u[c].iter_mut() {
                *v = Cpx::new(rng.normal(), 0.0);
            }
        }
        let obs = emap.gather_observations(&u);
        assert_eq!(obs.len(), emap.n_elems() * p * p * p * 3);
        // Spot-check random entries against direct indexing.
        for _ in 0..20 {
            let (ex, ey, ez) = (rng.below(e), rng.below(e), rng.below(e));
            let (lx, ly, lz) = (rng.below(p), rng.below(p), rng.below(p));
            let c = rng.below(3);
            let elem_row = (ez * e + ey) * e + ex;
            let local = (lz * p + ly) * p + lx;
            let obs_idx = (elem_row * p * p * p + local) * 3 + c;
            let gi = grid.idx(ex * p + lx, ey * p + ly, ez * p + lz);
            assert!((obs[obs_idx] as f64 - u[c][gi].re).abs() < 1e-6);
        }
    }
}

#[test]
fn element_cs_only_affects_owned_region_dissipation() {
    // Eddy viscosity with Cs > 0 in ONE element must dissipate energy
    // relative to the implicit run, but less than Cs > 0 everywhere.
    let mut rng = Rng::new(8);
    let grid = Grid::new(12);
    let state = random_solenoidal(&grid, 1.0, 3.0, &mut rng);
    let run = |cs: Vec<f64>| {
        let mut s = Solver::new(12, 2, 0.01, 0.4);
        s.set_state(relexi::solver::spectral::clone_vec(&state));
        s.set_cs(&cs);
        s.advance(0.15);
        s.kinetic_energy()
    };
    let ke_none = run(vec![0.0; 8]);
    let mut one = vec![0.0; 8];
    one[3] = 0.3;
    let ke_one = run(one);
    let ke_all = run(vec![0.3; 8]);
    assert!(ke_all < ke_one && ke_one < ke_none, "{ke_all} < {ke_one} < {ke_none}");
}
