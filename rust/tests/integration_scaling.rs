//! Integration: the scaling experiments (Figs. 3-4) regenerate with the
//! paper's qualitative shape on the simulated Hawk partition.

use relexi::hpc::{
    steps_per_action_for, strong_scaling, weak_scaling, ClusterSim, IterationParams,
};
use relexi::launcher::{LaunchMode, StagingMode};

#[test]
fn fig3_weak_scaling_shape_both_cases() {
    let sim = ClusterSim::hawk(16);
    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        for ranks in [2usize, 4, 8, 16] {
            let pts = weak_scaling(&sim, dof, ranks, spa).unwrap();
            // Covers 2 envs up to the full partition.
            assert_eq!(pts.first().unwrap().n_envs, 2);
            assert_eq!(pts.last().unwrap().n_envs, 2048 / ranks);
            // Speedup grows monotonically with envs (parallelism wins) ...
            for w in pts.windows(2) {
                assert!(
                    w[1].speedup > w[0].speedup,
                    "{dof} DOF, {ranks} ranks: speedup not monotone"
                );
            }
            // ... while efficiency never exceeds ideal and decays overall.
            for p in &pts {
                assert!(p.efficiency <= 1.05, "superlinear at {p:?}");
            }
            assert!(pts.last().unwrap().efficiency < pts.first().unwrap().efficiency);
        }
    }
}

#[test]
fn fig3_two_rank_dip_from_die_sharing() {
    // The paper's counterintuitive §6.1 observation: going from one to two
    // 2-rank envs slows the envs down (shared die bandwidth), visible as a
    // sub-ideal 2-env speedup, while 16-rank envs show (almost) none of it.
    let sim = ClusterSim::hawk(16);
    let sp2 = sim
        .speedup(&IterationParams::for_case(24, 2, 2))
        .unwrap();
    let sp16 = sim
        .speedup(&IterationParams::for_case(24, 2, 16))
        .unwrap();
    let dip2 = 2.0 - sp2;
    let dip16 = 2.0 - sp16;
    assert!(
        dip2 > dip16,
        "2-rank dip ({dip2:.3}) should exceed 16-rank dip ({dip16:.3})"
    );
}

#[test]
fn fig4_strong_scaling_shape_both_cases() {
    let sim = ClusterSim::hawk(16);
    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        for envs in [2usize, 8, 32, 128] {
            let pts = strong_scaling(&sim, dof, envs, &[2, 4, 8, 16], spa).unwrap();
            assert!(!pts.is_empty());
            // Baseline point is ideal by definition.
            assert!((pts[0].speedup - pts[0].ranks_per_env as f64).abs() < 1e-9);
            // Efficiency decays with ranks (per-core load shrinks).
            for w in pts.windows(2) {
                assert!(
                    w[1].efficiency <= w[0].efficiency + 0.02,
                    "{dof} DOF {envs} envs: efficiency should not grow with ranks"
                );
            }
        }
    }
}

#[test]
fn head_work_hurts_high_env_counts_more() {
    // §6.1: "if the necessary time to compute the FLEXI simulation
    // decreases [more ranks], the sequential work of Relexi becomes more
    // dominant, which decreases the scaling efficiency."
    let sim = ClusterSim::hawk(16);
    let eff = |envs: usize, ranks: usize| {
        sim.speedup(&IterationParams::for_case(24, envs, ranks)).unwrap() / envs as f64
    };
    assert!(eff(128, 2) > eff(128, 16));
}

#[test]
fn launch_overhead_negligible_only_with_mpmd() {
    let sim = ClusterSim::hawk(16);
    let mut p = IterationParams::for_case(24, 256, 4);
    p.launch_mode = LaunchMode::Mpmd;
    p.staging = StagingMode::RamDrive;
    let fast = sim.simulate(&p).unwrap();
    assert!(
        fast.launch_s < 0.3 * fast.sampling_s,
        "MPMD launch should be small vs sampling: {:.1}s vs {:.1}s",
        fast.launch_s,
        fast.sampling_s
    );

    p.launch_mode = LaunchMode::Individual;
    p.staging = StagingMode::Lustre;
    let slow = sim.simulate(&p).unwrap();
    assert!(
        slow.launch_s > fast.launch_s * 10.0,
        "naive launch should dominate: {:.1}s vs {:.1}s",
        slow.launch_s,
        fast.launch_s
    );
}

#[test]
fn paper_wallclock_scale_16_and_64_envs() {
    // §6.2: sampling 15 s (16 envs) and 18 s (64 envs) per iteration at
    // 8 ranks/env — the simulated times must land in that neighbourhood
    // and grow sublinearly (parallel envs).
    let sim = ClusterSim::hawk(16);
    let t16 = sim
        .simulate(&IterationParams::for_case(24, 16, 8))
        .unwrap()
        .sampling_s;
    let t64 = sim
        .simulate(&IterationParams::for_case(24, 64, 8))
        .unwrap()
        .sampling_s;
    assert!((8.0..35.0).contains(&t16), "t16={t16:.1}s");
    assert!(t64 > t16, "more envs => slightly slower iteration");
    assert!(
        t64 < 2.0 * t16,
        "sampling must grow sublinearly: {t16:.1}s -> {t64:.1}s"
    );
}
