//! End-to-end learning smoke: real PPO through the full stack — worker
//! pool, orchestrator, event-driven collector, native policy/trainer —
//! with **zero compiled artifacts**, so it runs in every CI container.
//!
//! * The Burgers leg (`learning_smoke_burgers_native_improves`) is the
//!   headline gate: a 64-env pool trains for a handful of iterations and
//!   the mean normalized return must IMPROVE over the iteration-0
//!   (random-init) baseline, with every `TrainMetrics` diagnostic
//!   finite.  Improvement is asserted twice: on the noise-free
//!   deterministic test-state evaluation (same held-out state, mean
//!   actions, pinned env noise — the policy is the only thing that
//!   changes) and on the sampled training returns (last third vs
//!   iteration 0).
//! * The LES leg (`learning_smoke_les_native_runs`) drives the same
//!   native runtime on the 3D spectral backend at CI scale (2 envs):
//!   gradients flow, metrics stay finite, checkpoints round-trip.  Two
//!   iterations cannot assert learning on a 12^3 LES; the Burgers leg
//!   owns the improvement gate.

use relexi::config::{BurgersConfig, CaseConfig, RunConfig};
use relexi::coordinator::{MetricsLog, TrainingLoop};
use relexi::runtime::Trainer;
use relexi::solver::dns::{generate, TruthParams};
use std::sync::Arc;

fn assert_history_finite(log: &MetricsLog) {
    for m in &log.history {
        assert!(
            m.return_mean.is_finite() && m.return_min.is_finite() && m.return_max.is_finite(),
            "iteration {}: non-finite returns",
            m.iteration
        );
        assert!(
            m.loss.is_finite() && m.clip_frac.is_finite() && m.approx_kl.is_finite(),
            "iteration {}: non-finite train metrics (loss {}, clip {}, kl {})",
            m.iteration,
            m.loss,
            m.clip_frac,
            m.approx_kl
        );
        assert!((0.0..=1.0).contains(&m.clip_frac), "clip_frac out of range");
    }
}

#[test]
fn learning_smoke_burgers_native_improves() {
    let mut cfg = RunConfig::default();
    cfg.rl.backend = "burgers".to_string();
    cfg.runtime.backend = "native".to_string();
    // A small-capacity net and a CI-friendly learning rate: ~800 Adam
    // steps over 10 iterations move the initial mean (Cs ~ 0.25
    // everywhere) decisively within the run budget.
    cfg.runtime.hidden = vec![32];
    cfg.runtime.lr = 3e-3;
    // Scenario chosen (via a Python oracle sweep of constant-Cs returns)
    // so the reward has real curvature in Cs: k_max = 16 scores the
    // spectrum tail the SGS term acts on, alpha = 0.1 keeps the reward
    // off its saturation plateau, and the 20-action horizon lets
    // under/over-dissipation accumulate.  Constant-Cs returns run from
    // ~-0.5 (Cs = 0) through ~0.56 (the 0.25 init) to ~0.82 (optimal
    // Cs ~ 0.3) — a steep, smooth, unimodal slope for PPO to climb.
    cfg.burgers = BurgersConfig {
        points: 48,
        segments: 4,
        k_max: 16,
        alpha: 0.1,
        t_end: 2.0, // 20 actions per episode
        truth_states: 4,
        truth_spinup: 1.0,
        truth_interval: 0.25,
        ..BurgersConfig::default()
    };
    cfg.rl.n_envs = 64;
    cfg.rl.iterations = 10;
    cfg.rl.epochs = 4;
    cfg.rl.minibatch = 256;
    cfg.rl.eval_every = 0; // eval handled explicitly below
    cfg.rl.seed = 7;
    cfg.out_dir = std::env::temp_dir()
        .join("relexi_learning_smoke_burgers")
        .to_string_lossy()
        .to_string();

    let mut lp = TrainingLoop::from_config(cfg, None).expect("artifact-free construction");
    let theta0 = lp.trainer.theta().to_vec();
    let before = lp.evaluate().expect("init eval").normalized_return;

    let mut log = MetricsLog::in_memory();
    lp.run(&mut log).expect("training run");

    assert_eq!(log.history.len(), 10);
    assert_history_finite(&log);
    assert!(
        lp.trainer.theta().iter().all(|x| x.is_finite()),
        "parameters diverged"
    );
    assert!(
        lp.trainer.theta().iter().zip(&theta0).any(|(a, b)| a != b),
        "no gradient flowed"
    );
    // 10 iterations x 4 epochs x (64 envs * 20 steps * 4 agents / 256).
    assert!(lp.trainer.opt_step() >= 10.0 * 4.0 * 20.0);

    // Gate 1 — deterministic test-state evaluation: same held-out
    // state, mean actions, pinned env noise; the policy is the only
    // difference between the two rollouts.
    let after = lp.evaluate().expect("final eval").normalized_return;
    assert!(
        after > before,
        "native PPO failed to improve the deterministic test-state return: \
         {before:.4} -> {after:.4}"
    );

    // Gate 2 — sampled training returns: the mean over the final third
    // of the run must beat the iteration-0 (random-init) baseline.
    let baseline = log.history[0].return_mean;
    let tail: Vec<f64> = log.history[7..].iter().map(|m| m.return_mean).collect();
    let tail_mean = tail.iter().sum::<f64>() / tail.len() as f64;
    assert!(
        tail_mean > baseline,
        "mean sampled return did not improve over the random-init iteration: \
         it0 {baseline:.4} vs mean(it7..9) {tail_mean:.4}"
    );
}

#[test]
fn learning_smoke_les_native_runs() {
    // Tiny 12^3 / 2^3-element LES case, native runtime: the 3D backend
    // trains artifact-free through the same path the Burgers leg gates.
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "tiny".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    cfg.solver.t_end = 0.3; // 3 actions per episode
    cfg.solver.dns_points = 24;
    cfg.runtime.backend = "native".to_string();
    cfg.runtime.hidden = vec![16];
    cfg.rl.n_envs = 2;
    cfg.rl.iterations = 2;
    cfg.rl.epochs = 2;
    cfg.rl.minibatch = 16;
    cfg.rl.eval_every = 1;
    cfg.out_dir = std::env::temp_dir()
        .join("relexi_learning_smoke_les")
        .to_string_lossy()
        .to_string();

    let truth = Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: cfg.solver.nu,
            ke_target: cfg.solver.ke_target,
            spinup_time: 0.5,
            n_states: 3,
            sample_interval: 0.2,
            seed: 61,
        },
        |_, _| {},
    ));

    let mut lp = TrainingLoop::new(cfg.clone(), truth).expect("native les construction");
    let theta0 = lp.trainer.theta().to_vec();
    let mut log = MetricsLog::in_memory();
    lp.run(&mut log).expect("training run");

    assert_eq!(log.history.len(), 2);
    assert_history_finite(&log);
    for m in &log.history {
        assert!(m.test_return.is_some(), "eval_every=1 -> eval every iteration");
        assert!(m.test_return.unwrap().is_finite());
    }
    assert!(
        lp.trainer.theta().iter().zip(&theta0).any(|(a, b)| a != b),
        "no gradient flowed through the LES path"
    );

    // The flat-theta checkpoint round-trips through the binio format.
    let ckpt = std::path::Path::new(&cfg.out_dir).join("policy_final.bin");
    assert!(ckpt.exists(), "final checkpoint missing");
    let saved = lp.trainer.theta().to_vec();
    lp.load_checkpoint(&ckpt).expect("checkpoint reload");
    assert_eq!(lp.trainer.theta(), &saved[..]);
    assert_eq!(lp.trainer.opt_step(), 0.0, "reload resets the optimizer");
    // A wrong-architecture checkpoint is rejected by the length check.
    let bad = std::path::Path::new(&cfg.out_dir).join("bad.bin");
    relexi::util::binio::write_f32_vec(&bad, &[0.0; 7]).unwrap();
    assert!(lp.load_checkpoint(&bad).is_err());
}
