//! Integration: the AOT bridge end to end.  Loads the HLO-text artifacts
//! produced by `make artifacts`, executes them via PJRT and compares
//! against the test vectors JAX computed at lowering time
//! (`artifacts/testvec.json` + `testvec_obs_n*.bin`).  This is the proof
//! that the Rust hot path computes exactly what the Python model defines.

use relexi::runtime::{ArtifactKind, Minibatch, PolicyRuntime, Registry, Runtime, TrainerRuntime};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn policy_and_trainstep_match_jax() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let reg = Registry::open(&artifacts_dir()).unwrap();
    let tv_all = reg.testvec().unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut checked = 0;

    for n in [5usize, 7] {
        let tv = match tv_all.get(&n.to_string()) {
            Ok(t) => t,
            Err(_) => continue,
        };
        let b = tv.get("batch").unwrap().num().unwrap() as usize;
        let theta = reg.initial_params(n).unwrap();

        let obs_path = artifacts_dir().join(format!("testvec_obs_n{n}.bin"));
        if !obs_path.exists() {
            eprintln!("skipping n={n}: no testvec obs dump (rerun make artifacts)");
            continue;
        }
        let obs = relexi::util::binio::read_f32_vec(&obs_path).unwrap();
        let feat = (n + 1).pow(3) * 3;
        assert_eq!(obs.len(), b * feat);
        let first8 = tv.get("obs_first8").unwrap().f32_vec().unwrap();
        for (i, want) in first8.iter().enumerate() {
            assert!((obs[i] - want).abs() < 1e-6, "obs[{i}]");
        }

        // --- policy forward -------------------------------------------
        let policy = PolicyRuntime::load(&rt, &reg, n).unwrap();
        let out = policy.forward(&theta, &obs, b).unwrap();
        let want_mean = tv.get("mean").unwrap().f32_vec().unwrap();
        let want_value = tv.get("value").unwrap().f32_vec().unwrap();
        let want_logstd = tv.get("log_std").unwrap().num().unwrap() as f32;
        assert_eq!(out.mean.len(), b);
        for i in 0..b {
            assert!(
                (out.mean[i] - want_mean[i]).abs() < 1e-5,
                "n={n} mean[{i}]: {} vs {}",
                out.mean[i],
                want_mean[i]
            );
            assert!(
                (out.value[i] - want_value[i]).abs() < 2e-4,
                "n={n} value[{i}]: {} vs {}",
                out.value[i],
                want_value[i]
            );
        }
        assert!((out.log_std - want_logstd).abs() < 1e-6);

        // --- train step -----------------------------------------------
        let batches = reg.batches(ArtifactKind::TrainStep, n);
        assert!(
            batches.contains(&b),
            "testvec batch {b} has no train_step artifact ({batches:?})"
        );
        let mut trainer = TrainerRuntime::load(&rt, &reg, n, b).unwrap();
        let act = tv.get("act").unwrap().f32_vec().unwrap();
        let old_logp = tv.get("old_logp").unwrap().f32_vec().unwrap();
        let adv = tv.get("adv").unwrap().f32_vec().unwrap();
        let ret = tv.get("ret").unwrap().f32_vec().unwrap();
        let m = trainer
            .train_minibatch(&Minibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
            })
            .unwrap();
        let want_loss = tv.get("train_loss").unwrap().num().unwrap() as f32;
        assert!(
            (m.loss - want_loss).abs() < 1e-4 * want_loss.abs().max(1.0),
            "n={n} loss {} vs {}",
            m.loss,
            want_loss
        );
        let want_clip = tv.get("train_clipfrac").unwrap().num().unwrap() as f32;
        assert!((m.clip_frac - want_clip).abs() < 1e-5);
        let want_theta8 = tv.get("theta2_first8").unwrap().f32_vec().unwrap();
        for (i, want) in want_theta8.iter().enumerate() {
            assert!(
                (trainer.theta()[i] - want).abs() < 1e-5,
                "n={n} theta'[{i}]: {} vs {}",
                trainer.theta()[i],
                want
            );
        }
        assert_eq!(trainer.opt_step(), 1.0);
        let l2: f64 = trainer
            .theta()
            .iter()
            .map(|&x| (x as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let want_l2 = tv.get("theta2_l2").unwrap().num().unwrap();
        assert!(
            (l2 - want_l2).abs() < 1e-3 * want_l2,
            "n={n} |theta'| {l2} vs {want_l2}"
        );
        checked += 1;
    }
    assert!(checked >= 1, "no model variant was actually verified");
}

#[test]
fn policy_chunking_consistent_across_batch_sizes() {
    if !have_artifacts() {
        return;
    }
    let reg = Registry::open(&artifacts_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let n = 5usize;
    let policy = PolicyRuntime::load(&rt, &reg, n).unwrap();
    let theta = reg.initial_params(n).unwrap();
    let feat = policy.features();

    // 100 samples force a 64-chunk + padded chunk; results must equal
    // evaluating each row alone (padded single-sample calls).
    let mut rng = relexi::util::Rng::new(3);
    let obs: Vec<f32> = (0..100 * feat).map(|_| rng.normal() as f32).collect();
    let out_chunked = policy.forward(&theta, &obs, 100).unwrap();
    assert_eq!(out_chunked.mean.len(), 100);

    for i in [0usize, 37, 63, 64, 99] {
        let one = policy
            .forward(&theta, &obs[i * feat..(i + 1) * feat], 1)
            .unwrap();
        assert!(
            (one.mean[0] - out_chunked.mean[i]).abs() < 1e-5,
            "sample {i}: {} vs {}",
            one.mean[0],
            out_chunked.mean[i]
        );
        assert!((one.value[0] - out_chunked.value[i]).abs() < 2e-4);
    }
}

#[test]
fn policy_mean_in_admissible_range() {
    if !have_artifacts() {
        return;
    }
    let reg = Registry::open(&artifacts_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let policy = PolicyRuntime::load(&rt, &reg, 5).unwrap();
    let theta = reg.initial_params(5).unwrap();
    let feat = policy.features();
    let mut rng = relexi::util::Rng::new(9);
    // Extreme inputs: the scale layer must still bound Cs to [0, 0.5].
    let obs: Vec<f32> = (0..64 * feat).map(|_| (rng.normal() * 50.0) as f32).collect();
    let out = policy.forward(&theta, &obs, 64).unwrap();
    for m in &out.mean {
        assert!((0.0..=0.5).contains(m), "mean {m} outside [0, 0.5]");
    }
}
