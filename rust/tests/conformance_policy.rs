//! Policy/trainer runtime conformance: every backend registered in
//! `config::RUNTIME_BACKENDS` must satisfy the contract the rollout and
//! training stacks rely on (see `runtime::api`):
//!
//! * shape agreement — `forward` on `n` samples returns exactly `n`
//!   means and `n` values, for any `n`, and `policy.features()` matches
//!   what the pair was constructed for;
//! * `log_std` finite, means finite and inside the admissible
//!   `[0, 0.5]` Cs range, values finite;
//! * deterministic forward — same `theta` + `obs` twice gives
//!   bitwise-identical outputs;
//! * trainer/policy pairing — the trainer's `theta` feeds the policy's
//!   `forward` directly, `train_minibatch` advances the optimizer with
//!   finite metrics, `set_theta` length-checks and resets.
//!
//! The XLA backend needs its compiled artifacts on disk and self-skips
//! without them (same convention as `integration_runtime`); the native
//! backend always runs, so CI exercises the contract on every push.

use relexi::config::RunConfig;
use relexi::runtime::{runtime_from_config, Minibatch, Policy, Trainer};
use relexi::util::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build every constructible runtime backend: `(label, policy, trainer)`.
fn all_runtimes() -> Vec<(String, Box<dyn Policy>, Box<dyn Trainer>)> {
    let mut out = Vec::new();
    for &name in relexi::config::RUNTIME_BACKENDS {
        let mut cfg = RunConfig::default();
        cfg.runtime.backend = name.to_string();
        cfg.artifacts_dir = artifacts_dir().to_string_lossy().to_string();
        // The native pair sizes itself from this; the XLA pair ignores
        // it (its features come from the N=5 artifacts: 648).
        let features = if name == "xla" { 648 } else { 12 };
        if name == "xla" && !Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
            eprintln!("skipping runtime backend {name:?}: run `make artifacts` first");
            continue;
        }
        cfg.rl.minibatch = 256;
        let (policy, trainer) = runtime_from_config(&cfg, features)
            .unwrap_or_else(|e| panic!("runtime backend {name:?} failed to construct: {e:#}"));
        out.push((name.to_string(), policy, trainer));
    }
    assert!(
        !out.is_empty(),
        "no runtime backend constructible (native must always be)"
    );
    out
}

#[test]
fn registry_covers_every_declared_runtime_backend() {
    // Unknown names must fail at resolution with the declared list.
    let mut cfg = RunConfig::default();
    cfg.runtime.backend = "tpu".to_string();
    let err = runtime_from_config(&cfg, 8).unwrap_err();
    assert!(format!("{err:#}").contains("runtime.backend"));
    // The native backend resolves without any artifacts directory.
    cfg.runtime.backend = "native".to_string();
    cfg.artifacts_dir = "/nonexistent".to_string();
    assert!(runtime_from_config(&cfg, 8).is_ok());
}

#[test]
fn forward_shapes_agree_for_every_batch_size() {
    for (name, policy, trainer) in all_runtimes() {
        let feat = policy.features();
        assert!(feat >= 1, "{name}");
        assert!(!trainer.theta().is_empty(), "{name}: trainer must own parameters");
        let mut rng = Rng::new(11);
        for n in [1usize, 5, 64] {
            let obs: Vec<f32> = (0..n * feat).map(|_| rng.normal() as f32).collect();
            let out = policy
                .forward(trainer.theta(), &obs, n)
                .unwrap_or_else(|e| panic!("{name}: forward n={n}: {e:#}"));
            assert_eq!(out.mean.len(), n, "{name}: mean count for n={n}");
            assert_eq!(out.value.len(), n, "{name}: value count for n={n}");
        }
        // Mismatched obs length is rejected, not silently truncated.
        let bad = vec![0.0f32; feat + 1];
        assert!(policy.forward(trainer.theta(), &bad, 1).is_err(), "{name}");
    }
}

#[test]
fn outputs_are_finite_and_means_admissible() {
    for (name, policy, trainer) in all_runtimes() {
        let feat = policy.features();
        let mut rng = Rng::new(23);
        // Extreme inputs included: the mean head must stay bounded.
        let obs: Vec<f32> = (0..32 * feat)
            .map(|_| (rng.normal() * 20.0) as f32)
            .collect();
        let out = policy.forward(trainer.theta(), &obs, 32).unwrap();
        assert!(out.log_std.is_finite(), "{name}: log_std {}", out.log_std);
        for (i, m) in out.mean.iter().enumerate() {
            assert!(
                m.is_finite() && (0.0..=0.5).contains(m),
                "{name}: mean[{i}] = {m} outside [0, 0.5]"
            );
        }
        assert!(
            out.value.iter().all(|v| v.is_finite()),
            "{name}: non-finite value"
        );
    }
}

#[test]
fn forward_is_bitwise_deterministic() {
    for (name, policy, trainer) in all_runtimes() {
        let feat = policy.features();
        let mut rng = Rng::new(31);
        let obs: Vec<f32> = (0..9 * feat).map(|_| rng.normal() as f32).collect();
        let a = policy.forward(trainer.theta(), &obs, 9).unwrap();
        let b = policy.forward(trainer.theta(), &obs, 9).unwrap();
        assert!(
            a.mean.iter().zip(&b.mean).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{name}: nondeterministic mean"
        );
        assert!(
            a.value.iter().zip(&b.value).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{name}: nondeterministic value"
        );
        assert_eq!(a.log_std.to_bits(), b.log_std.to_bits(), "{name}");
    }
}

#[test]
fn trainer_steps_and_checkpoints_conform() {
    for (name, policy, mut trainer) in all_runtimes() {
        let feat = policy.features();
        let b = trainer.minibatch();
        assert!(b >= 1, "{name}");
        let theta0 = trainer.theta().to_vec();
        assert_eq!(trainer.opt_step(), 0.0, "{name}: fresh trainer");

        let mut rng = Rng::new(47);
        let obs: Vec<f32> = (0..b * feat).map(|_| rng.normal() as f32).collect();
        let act: Vec<f32> = (0..b).map(|_| rng.uniform_f32() * 0.5).collect();
        let old_logp = vec![-1.0f32; b];
        let adv: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
        let ret: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
        let m = trainer
            .train_minibatch(&Minibatch {
                obs: &obs,
                act: &act,
                old_logp: &old_logp,
                adv: &adv,
                ret: &ret,
            })
            .unwrap_or_else(|e| panic!("{name}: train_minibatch: {e:#}"));
        for (what, x) in [
            ("loss", m.loss),
            ("pg_loss", m.pg_loss),
            ("v_loss", m.v_loss),
            ("entropy", m.entropy),
            ("clip_frac", m.clip_frac),
            ("approx_kl", m.approx_kl),
        ] {
            assert!(x.is_finite(), "{name}: {what} = {x}");
        }
        assert_eq!(trainer.opt_step(), 1.0, "{name}: one step taken");
        assert!(
            trainer.theta().iter().zip(&theta0).any(|(a, b)| a != b),
            "{name}: parameters unchanged after a train step"
        );
        // The updated theta still drives the policy.
        let out = policy.forward(trainer.theta(), &obs[..feat], 1).unwrap();
        assert!(out.mean[0].is_finite(), "{name}");

        // A wrong-size minibatch is rejected on every backend (the
        // static XLA artifact and the native trainer share the
        // exact-size contract).
        if b > 1 {
            let short = Minibatch {
                obs: &obs[..feat],
                act: &act[..1],
                old_logp: &old_logp[..1],
                adv: &adv[..1],
                ret: &ret[..1],
            };
            assert!(
                trainer.train_minibatch(&short).is_err(),
                "{name}: short minibatch must be rejected"
            );
        }

        // set_theta: wrong length rejected, right length resets.
        assert!(trainer.set_theta(vec![0.0; 3]).is_err(), "{name}");
        trainer.set_theta(theta0.clone()).unwrap();
        assert_eq!(trainer.opt_step(), 0.0, "{name}: reset optimizer");
        assert_eq!(trainer.theta(), &theta0[..], "{name}: theta restored");
    }
}
