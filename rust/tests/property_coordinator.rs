//! Property tests on coordinator invariants (proptest-style with our own
//! deterministic generators): rank placement, trajectory math, dataset
//! batching, config round-trips, and the key protocol.

use relexi::config::toml::Toml;
use relexi::hpc::Topology;
use relexi::launcher::place;
use relexi::orchestrator::Protocol;
use relexi::rl::{flatten, Episode, StepRecord};
use relexi::util::Rng;
use std::collections::HashSet;

/// Deterministic pseudo-random cases (seeded sweep = reproducible).
fn cases(n: usize, seed: u64) -> impl Iterator<Item = Rng> {
    (0..n).map(move |i| Rng::new(seed.wrapping_add(i as u64 * 0x9E37)))
}

// --- placement invariants ----------------------------------------------------

#[test]
fn placement_never_double_occupies_and_never_straddles() {
    for mut rng in cases(200, 1) {
        let nodes = 1 + rng.below(16);
        let topo = Topology::hawk(nodes);
        let ranks = [1usize, 2, 4, 8, 16, 32][rng.below(6)];
        let max_inst = (topo.cores_per_node / ranks) * nodes;
        let n_inst = 1 + rng.below(max_inst);
        let p = match place(&topo, n_inst, ranks) {
            Ok(p) => p,
            Err(e) => panic!("capacity said ok but place failed: {e}"),
        };
        // No double occupancy:
        let mut seen = HashSet::new();
        for pin in &p.pins {
            assert!(seen.insert((pin.node, pin.core)));
        }
        // All ranks of an instance on one node:
        let mut node_of = vec![usize::MAX; n_inst];
        for pin in &p.pins {
            if node_of[pin.instance] == usize::MAX {
                node_of[pin.instance] = pin.node;
            }
            assert_eq!(node_of[pin.instance], pin.node);
        }
        // Every instance has exactly `ranks` pins:
        let mut counts = vec![0usize; n_inst];
        for pin in &p.pins {
            counts[pin.instance] += 1;
        }
        assert!(counts.iter().all(|&c| c == ranks));
        // Die occupancy sums to total ranks:
        assert_eq!(p.die_occupancy().iter().sum::<usize>(), n_inst * ranks);
    }
}

#[test]
fn placement_rejects_what_capacity_forbids() {
    for mut rng in cases(100, 2) {
        let topo = Topology::hawk(1 + rng.below(4));
        let ranks = 1 + rng.below(128);
        let capacity = (topo.cores_per_node / ranks) * topo.nodes;
        assert!(place(&topo, capacity + 1, ranks).is_err());
        if capacity > 0 {
            assert!(place(&topo, capacity, ranks).is_ok());
        }
    }
}

// --- trajectory invariants ----------------------------------------------------

fn random_episode(rng: &mut Rng, n_steps: usize, n_elems: usize, feat: usize) -> Episode {
    Episode {
        steps: (0..n_steps)
            .map(|_| StepRecord {
                obs: (0..n_elems * feat).map(|_| rng.normal() as f32).collect(),
                act: (0..n_elems).map(|_| rng.uniform_f32() * 0.5).collect(),
                logp: (0..n_elems).map(|_| -rng.uniform_f32()).collect(),
                value: (0..n_elems).map(|_| rng.normal() as f32 * 0.1).collect(),
                reward: rng.range(-1.0, 1.0),
            })
            .collect(),
        ..Episode::default()
    }
}

#[test]
fn flatten_sample_count_and_normalization() {
    for mut rng in cases(50, 3) {
        let n_eps = 1 + rng.below(5);
        let n_steps = 1 + rng.below(10);
        let n_elems = 1 + rng.below(8);
        let feat = 3 * (1 + rng.below(4));
        let eps: Vec<Episode> = (0..n_eps)
            .map(|_| random_episode(&mut rng, n_steps, n_elems, feat))
            .collect();
        let ds = flatten(&eps, feat, 0.99, 0.95);
        assert_eq!(ds.len(), n_eps * n_steps * n_elems);
        assert_eq!(ds.obs.len(), ds.len() * feat);
        // Advantages normalized (when more than one distinct sample):
        if ds.len() > 1 {
            let advs: Vec<f64> = ds.adv.iter().map(|&a| a as f64).collect();
            assert!(relexi::util::stats::mean(&advs).abs() < 1e-4);
        }
        // Returns bounded by reward bounds: |R| <= sum gamma^k <= n_steps.
        for &r in &ds.ret {
            assert!((r as f64).abs() <= n_steps as f64 + 1e-5);
        }
    }
}

#[test]
fn minibatch_partition_properties() {
    for mut rng in cases(50, 4) {
        let n_steps = 1 + rng.below(6);
        let n_elems = 1 + rng.below(6);
        let ep = random_episode(&mut rng, n_steps, n_elems, 3);
        let ds = flatten(&[ep], 3, 0.9, 1.0);
        let mb = 1 + rng.below(2 * ds.len());
        let batches = ds.minibatch_indices(mb, &mut rng);
        // Every batch exactly mb indices; all indices valid; full coverage.
        let mut seen = vec![false; ds.len()];
        for b in &batches {
            assert_eq!(b.len(), mb);
            for &i in b {
                assert!(i < ds.len());
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "minibatches must cover the dataset");
        assert_eq!(batches.len(), ds.len().div_ceil(mb));
    }
}

#[test]
fn discounted_return_is_gamma_contraction() {
    // |R(tau)| <= r_max * gamma (1-gamma^n)/(1-gamma)
    for mut rng in cases(50, 5) {
        let n_steps = 1 + rng.below(50);
        let ep = random_episode(&mut rng, n_steps, 2, 3);
        let gamma: f64 = 0.995;
        let bound = gamma * (1.0 - gamma.powi(n_steps as i32)) / (1.0 - gamma);
        assert!(ep.discounted_return(gamma).abs() <= bound + 1e-9);
    }
}

// --- config + protocol invariants ---------------------------------------------

#[test]
fn toml_roundtrip_for_generated_configs() {
    for mut rng in cases(100, 6) {
        let n_envs = 1 + rng.below(1024);
        let t_end = (1 + rng.below(50)) as f64 / 10.0;
        let seed = rng.next_u64() % 100_000;
        let text = format!(
            "[rl]\nn_envs = {n_envs}\nseed = {seed}\n[solver]\nt_end = {t_end}\n"
        );
        let doc = Toml::parse(&text).unwrap();
        let cfg = relexi::config::RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.rl.n_envs, n_envs);
        assert_eq!(cfg.rl.seed, seed);
        assert!((cfg.solver.t_end - t_end).abs() < 1e-12);
    }
}

#[test]
fn protocol_keys_unique_across_space() {
    // No two (env, step, kind) combinations may collide.
    let p = Protocol::new("run");
    let mut seen = HashSet::new();
    for env in 0..32 {
        for step in 0..64 {
            assert!(seen.insert(p.state_key(env, step)));
            assert!(seen.insert(p.action_key(env, step)));
            assert!(seen.insert(p.reward_key(env, step)));
        }
        assert!(seen.insert(p.done_key(env)));
        assert!(seen.insert(p.fail_key(env)));
    }
    assert!(seen.insert(p.abort_key()));
}
