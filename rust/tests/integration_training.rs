//! Integration: the complete training loop on a tiny configuration —
//! parallel env workers, orchestrator dataflow, compiled policy/train-step
//! artifacts, metrics.  This is Algorithm 1 end to end.

use relexi::config::{CaseConfig, RunConfig};
use relexi::coordinator::{eval_baseline, MetricsLog, TrainingLoop};
use relexi::runtime::Trainer; // `lp.trainer` is a `Box<dyn Trainer>`
use relexi::solver::dns::{generate, TruthParams};
use std::path::Path;
use std::sync::Arc;

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn tiny_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "tiny".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    cfg.solver.t_end = 0.3; // 3 actions per episode
    cfg.solver.dns_points = 24;
    cfg.rl.n_envs = 3;
    cfg.rl.iterations = 2;
    cfg.rl.epochs = 2;
    cfg.rl.minibatch = 256;
    cfg.rl.eval_every = 1;
    cfg.out_dir = std::env::temp_dir()
        .join("relexi_it_training")
        .to_string_lossy()
        .to_string();
    cfg
}

#[test]
fn training_loop_runs_and_learns_plumbing() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = tiny_cfg();
    let truth = Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: cfg.solver.nu,
            ke_target: cfg.solver.ke_target,
            spinup_time: 0.5,
            n_states: 3,
            sample_interval: 0.2,
            seed: 33,
        },
        |_, _| {},
    ));

    let mut log = MetricsLog::in_memory();
    let mut lp = TrainingLoop::new(cfg.clone(), truth.clone()).unwrap();
    let theta_before: Vec<f32> = lp.trainer.theta().to_vec();
    lp.run(&mut log).unwrap();

    // Two iterations recorded with sane values.
    assert_eq!(log.history.len(), 2);
    for m in &log.history {
        assert!(m.return_mean.is_finite());
        assert!(m.return_min <= m.return_mean && m.return_mean <= m.return_max);
        assert!((-1.0..=1.0).contains(&m.return_mean));
        assert!(m.sample_time_s > 0.0);
        assert!(m.train_time_s > 0.0);
        assert!(m.test_return.is_some(), "eval_every=1 -> every iteration");
    }

    // The worker pool persisted across iterations: threads and envs were
    // built exactly once, in TrainingLoop::new.
    let counters = lp.pool_counters();
    assert_eq!(counters.threads_spawned, cfg.rl.n_envs);
    assert_eq!(counters.envs_built, cfg.rl.n_envs);
    assert_eq!(counters.grids_built, 1);
    assert_eq!(counters.iterations, 2);

    // Parameters actually moved (the PPO update executed).
    let theta_after = lp.trainer.theta();
    let moved: f64 = theta_before
        .iter()
        .zip(theta_after)
        .map(|(a, b)| (*a as f64 - *b as f64).abs())
        .sum();
    assert!(moved > 0.0, "parameters unchanged after training");
    // Optimizer stepped epochs x minibatches x iterations times.
    assert!(lp.trainer.opt_step() >= 4.0);

    // Final checkpoint written.
    assert!(Path::new(&cfg.out_dir).join("policy_final.bin").exists());

    // Checkpoint loads back.
    lp.load_checkpoint(&Path::new(&cfg.out_dir).join("policy_final.bin"))
        .unwrap();
}

#[test]
fn baselines_bracket_physics() {
    // Smagorinsky dissipates; implicit doesn't: at identical initial
    // states, the final spectra must differ and the Smagorinsky tail must
    // carry less energy.
    let cfg = tiny_cfg();
    let truth = Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: cfg.solver.nu,
            ke_target: cfg.solver.ke_target,
            spinup_time: 0.5,
            n_states: 2,
            sample_interval: 0.2,
            seed: 44,
        },
        |_, _| {},
    ));
    let smag = eval_baseline(&cfg, &truth, 0.17).unwrap();
    let implicit = eval_baseline(&cfg, &truth, 0.0).unwrap();
    let k_hi = truth.mean_spectrum.len() - 1;
    assert!(
        smag.final_spectrum[k_hi] < implicit.final_spectrum[k_hi],
        "Smagorinsky should damp the spectrum tail: {} vs {}",
        smag.final_spectrum[k_hi],
        implicit.final_spectrum[k_hi]
    );
}
