//! Integration: the persistent event-driven environment runtime.
//!
//! These tests drive the real worker pool (OS threads, real LES solver,
//! real orchestrator traffic) through `EnvPool::collect_with` with a
//! deterministic closure standing in for the compiled policy, so they run
//! without `make artifacts`:
//!
//! * steady-state iterations spawn zero threads and rebuild zero
//!   `LesEnv`/`Grid` instances (the PR's acceptance counter test);
//! * event-driven full-batch collection reproduces the lock-step
//!   reference bit-for-bit under a fixed seed — including heterogeneous
//!   pools where a short-horizon variant terminates early (the
//!   early-done deadlock regression);
//! * `min_batch = 1` (fully event-driven) still completes every episode
//!   with correct per-variant bookkeeping;
//! * the zero-copy exchange allocates no tensor buffers after the warm-up
//!   iteration (`PoolCounters::exchange_allocs`, the CI allocation gate).

use relexi::config::{BurgersConfig, CaseConfig, EnvVariant, RunConfig};
use relexi::coordinator::EnvPool;
use relexi::orchestrator::{Orchestrator, Protocol};
use relexi::rl::{flatten, BurgersBackend, Episode};
use relexi::runtime::stub_policy;
use relexi::solver::dns::{generate, Truth, TruthParams};
use relexi::util::Rng;
use std::sync::Arc;

/// Tiny 12^3 / 2^3-element case: 3 actions per episode at t_end = 0.3.
fn tiny_cfg(n_envs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "tiny".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    cfg.solver.t_end = 0.3;
    cfg.solver.dns_points = 24;
    cfg.rl.n_envs = n_envs;
    cfg
}

fn tiny_truth(seed: u64) -> Arc<Truth> {
    Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: 1.0 / 45.0,
            ke_target: 1.5,
            spinup_time: 0.5,
            n_states: 3,
            sample_interval: 0.2,
            seed,
        },
        |_, _| {},
    ))
}

/// Three scenario families: base, a half-horizon variant (terminates two
/// steps early relative to the base 4-step episode) and a high-viscosity
/// variant, with disjoint initial-state families.
fn heterogeneous_cfg() -> RunConfig {
    let mut cfg = tiny_cfg(4);
    cfg.solver.t_end = 0.4; // base horizon: 4 actions
    cfg.rl.variants = vec![
        EnvVariant::default(),
        EnvVariant {
            name: "short".into(),
            t_end_scale: 0.5,
            ..EnvVariant::default()
        },
        EnvVariant {
            name: "visc".into(),
            nu_scale: 2.0,
            alpha: Some(0.8),
            ..EnvVariant::default()
        },
    ];
    cfg.rl.split_init_pool = true;
    cfg
}

fn assert_episodes_identical(a: &[Episode], b: &[Episode]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.variant, y.variant, "env {i} variant");
        assert_eq!(x.steps.len(), y.steps.len(), "env {i} episode length");
        for (t, (sx, sy)) in x.steps.iter().zip(&y.steps).enumerate() {
            assert_eq!(sx.obs, sy.obs, "env {i} step {t} obs");
            assert_eq!(sx.act, sy.act, "env {i} step {t} act");
            assert_eq!(sx.logp, sy.logp, "env {i} step {t} logp");
            assert_eq!(sx.value, sy.value, "env {i} step {t} value");
            assert_eq!(
                sx.reward.to_bits(),
                sy.reward.to_bits(),
                "env {i} step {t} reward"
            );
        }
    }
}

#[test]
fn steady_state_spawns_nothing_and_rebuilds_nothing() {
    let cfg = tiny_cfg(3);
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(33), &orch).unwrap();

    let c0 = pool.counters();
    assert_eq!(c0.threads_spawned, n_envs);
    assert_eq!(c0.envs_built, n_envs);
    assert_eq!(c0.grids_built, 1);
    assert_eq!(c0.iterations, 0);

    let mut rng = Rng::new(5);
    for it in 0..3 {
        let proto = Protocol::new(&format!("it{it}"));
        let rollouts = pool
            .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs)
            .unwrap();
        orch.clear();
        assert_eq!(rollouts.episodes.len(), n_envs);
        for ep in &rollouts.episodes {
            assert_eq!(ep.steps.len(), 3, "t_end/dt_rl = 3 actions");
            for s in &ep.steps {
                assert!(s.reward.is_finite() && s.reward > -1.0 && s.reward <= 1.0);
            }
        }
        assert!(rollouts.sample_time_s > 0.0);
    }

    // The acceptance gate: iterations >= 1 spawned zero threads and
    // rebuilt zero LesEnv/Grid instances.
    let c1 = pool.counters();
    assert_eq!(c1.threads_spawned, c0.threads_spawned);
    assert_eq!(c1.envs_built, c0.envs_built);
    assert_eq!(c1.grids_built, c0.grids_built);
    assert_eq!(c1.iterations, 3);
}

#[test]
fn event_full_batch_matches_lockstep_bitwise() {
    // Same seed, same truth, two independent pools: the event-driven
    // collector at min_batch = n_envs must reproduce the lock-step
    // reference bit-for-bit — heterogeneous horizons included (the short
    // variant raises its done-flag two steps before the base horizon,
    // which deadlocked the seed's gather loop).
    let cfg = heterogeneous_cfg();
    let n_envs = cfg.rl.n_envs;
    let truth = tiny_truth(77);

    let orch_a = Orchestrator::launch(4);
    let mut pool_a = EnvPool::new(cfg.clone(), truth.clone(), &orch_a).unwrap();
    let mut rng_a = Rng::new(42);
    let lockstep = pool_a
        .collect_lockstep_with(
            &orch_a,
            &Protocol::new("cmp"),
            stub_policy,
            &mut rng_a,
            false,
        )
        .unwrap();

    let orch_b = Orchestrator::launch(4);
    let mut pool_b = EnvPool::new(cfg.clone(), truth, &orch_b).unwrap();
    let mut rng_b = Rng::new(42);
    let event = pool_b
        .collect_with(
            &orch_b,
            &Protocol::new("cmp"),
            stub_policy,
            &mut rng_b,
            false,
            n_envs,
        )
        .unwrap();

    assert_episodes_identical(&lockstep.episodes, &event.episodes);
    // And the trainer RNGs advanced identically.
    assert_eq!(rng_a.next_u64(), rng_b.next_u64());

    // Episode lengths follow the variants: 4 (base), 2 (short), 4 (visc),
    // 4 (base again, round-robin).
    let lens: Vec<usize> = event.episodes.iter().map(|e| e.steps.len()).collect();
    assert_eq!(lens, vec![4, 2, 4, 4]);
    let variants: Vec<usize> = event.episodes.iter().map(|e| e.variant).collect();
    assert_eq!(variants, vec![0, 1, 2, 0]);
}

#[test]
fn min_batch_one_completes_heterogeneous_pool() {
    let cfg = heterogeneous_cfg();
    let orch = Orchestrator::launch(4);
    let mut pool = EnvPool::new(cfg, tiny_truth(77), &orch).unwrap();
    let mut rng = Rng::new(9);
    let r = pool
        .collect_with(&orch, &Protocol::new("mb1"), stub_policy, &mut rng, false, 1)
        .unwrap();

    let lens: Vec<usize> = r.episodes.iter().map(|e| e.steps.len()).collect();
    assert_eq!(lens, vec![4, 2, 4, 4]);
    for ep in &r.episodes {
        for s in &ep.steps {
            assert!(s.reward.is_finite() && s.reward > -1.0 && s.reward <= 1.0);
            assert!(s.act.iter().all(|a| a.is_finite()));
        }
    }
    // The flattened dataset still has one row per element-sample.
    let feat = 6usize.pow(3) * 3;
    let ds = flatten(&r.episodes, feat, 0.995, 1.0);
    assert_eq!(ds.len(), (4 + 2 + 4 + 4) * 8);
}

#[test]
fn steady_state_exchange_allocates_nothing() {
    // The PR-3 acceptance gate (run explicitly by the CI smoke job): the
    // tensor pools — per-worker observation buffers, the trainer's action
    // buffers — warm up during iteration 0 and must never allocate again.
    // Rollouts are dropped before the next iteration (as the training
    // loop does after its update phase), which releases every shared
    // buffer back to its pool.  Since PR 7 the exchange sits behind the
    // transport seam: pin `transport = "inproc"` explicitly (the config
    // CI gates) and check the client really resolved to the direct-call
    // backend — the seam must not cost the fast path its zero-alloc
    // property.
    let mut cfg = tiny_cfg(3);
    cfg.orchestrator.transport = "inproc".to_string();
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    assert_eq!(orch.client().transport_kind(), "inproc");
    let mut pool = EnvPool::new(cfg, tiny_truth(21), &orch).unwrap();
    let mut rng = Rng::new(8);

    let mut allocs_after = Vec::new();
    for it in 0..4 {
        let proto = Protocol::new(&format!("za{it}"));
        let r = pool
            .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs)
            .unwrap();
        assert_eq!(r.episodes.len(), n_envs);
        orch.clear();
        allocs_after.push(pool.counters().exchange_allocs);
        // `r` (the only holder of the shared buffers) drops here.
    }
    assert!(
        allocs_after[0] > 0,
        "pools must warm up during iteration 0"
    );
    for it in 1..4 {
        assert_eq!(
            allocs_after[it],
            allocs_after[0],
            "iteration {it} allocated exchange buffers in steady state: {allocs_after:?}"
        );
    }
}

#[test]
fn steady_state_exchange_allocates_nothing_with_telemetry_on() {
    // PR-10 twin of the gate above: the instrumented hot path — wave
    // spans, exchange-wait histogram, frame events — must not cost the
    // exchange its zero-alloc steady state.  Telemetry's own ring
    // buffers warm up at thread registration (iteration 0 at the
    // latest) and are excluded from `exchange_allocs` by construction;
    // this proves the instrumentation doesn't push tensor traffic off
    // the pooled path.  The switch is process-wide but only this
    // binary's pool counters are asserted on, so parallel tests are
    // unaffected.
    relexi::util::telemetry::init(true, 65_536, "error", "trainer");
    let mut cfg = tiny_cfg(3);
    cfg.orchestrator.transport = "inproc".to_string();
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(22), &orch).unwrap();
    let mut rng = Rng::new(9);

    let mut allocs_after = Vec::new();
    for it in 0..4 {
        let proto = Protocol::new(&format!("zt{it}"));
        let r = pool
            .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs)
            .unwrap();
        assert_eq!(r.episodes.len(), n_envs);
        orch.clear();
        allocs_after.push(pool.counters().exchange_allocs);
    }

    // Prove the gate exercised the telemetry-ON path: the wave spans
    // must actually have been recorded.
    assert!(relexi::util::telemetry::enabled());
    let mut merger = relexi::util::telemetry::TraceMerger::new();
    merger.absorb_local();
    let summary = merger.summary();
    let collect = summary
        .spans
        .iter()
        .find(|s| s.name == "wave.collect")
        .expect("wave.collect spans must be recorded with telemetry on");
    assert!(collect.count >= 4, "one span per iteration: {}", collect.count);

    assert!(allocs_after[0] > 0, "pools must warm up during iteration 0");
    for it in 1..4 {
        assert_eq!(
            allocs_after[it],
            allocs_after[0],
            "iteration {it} allocated exchange buffers in steady state with telemetry on: {allocs_after:?}"
        );
    }
    relexi::util::telemetry::init(false, 65_536, "error", "trainer");
}

#[test]
fn collection_wave_subscription_ops_are_linear() {
    // The PR-4 acceptance counter: the event-driven collector holds one
    // persistent store subscription per sampling phase and applies only
    // single-key deltas per event, so a steady-state iteration over E
    // envs and T steps performs O(E*T) registry ops — O(E) per wave —
    // where the per-event rebuild it replaced performed O(E) ops per
    // EVENT (O(E^2) per wave).
    let cfg = tiny_cfg(4);
    let (n_envs, steps) = (cfg.rl.n_envs, 3usize);
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(19), &orch).unwrap();
    let mut rng = Rng::new(6);
    // Warm-up iteration (subscription behavior is identical, but keep
    // the measured iteration clean of any one-time effects).
    pool.collect_with(&orch, &Protocol::new("w0"), stub_policy, &mut rng, false, n_envs)
        .unwrap();
    orch.clear();
    let before = orch.stats().sub_ops;
    pool.collect_with(&orch, &Protocol::new("w1"), stub_policy, &mut rng, false, n_envs)
        .unwrap();
    orch.clear();
    let delta = orch.stats().sub_ops - before;
    // Exact budget: 3E setup adds + per (env, step) {state remove,
    // state add, reward add, reward remove} + 2E done retires + E fail
    // deregistrations on drop = 4ET + 6E.  Assert a small constant
    // multiple of E*(T+2) so bookkeeping tweaks don't break the test,
    // while any O(E^2)-per-wave regression trips it immediately.
    let linear_budget = (8 * n_envs * (steps + 2)) as u64;
    assert!(delta >= n_envs as u64, "subscription unused? {delta} ops");
    assert!(
        delta <= linear_budget,
        "collection wave not O(E): {delta} registry ops for {n_envs} envs x {steps} steps \
         (budget {linear_budget})"
    );
}

#[test]
fn smoke_burgers_training_iteration_64_envs() {
    // The Burgers backend's CI smoke: a full event-driven sampling
    // iteration with 64 envs — a scale the 3D LES cannot reach in CI —
    // across three scenario variants with disjoint initial-state
    // families, then the trajectory pipeline, with per-variant metrics
    // and the O(E) subscription-ops assertion at pool scale.
    let mut cfg = RunConfig::default();
    cfg.rl.backend = "burgers".to_string();
    cfg.burgers = BurgersConfig {
        points: 48,
        segments: 4,
        k_max: 6,
        t_end: 0.5, // 5 actions at the base horizon
        truth_states: 4,
        truth_spinup: 1.0,
        truth_interval: 0.25,
        ..BurgersConfig::default()
    };
    cfg.rl.n_envs = 64;
    cfg.rl.min_batch = 16; // genuinely event-driven batching
    cfg.rl.split_init_pool = true;
    cfg.rl.variants = vec![
        EnvVariant::default(),
        EnvVariant {
            name: "short".into(),
            t_end_scale: 0.6, // 3 actions: exercises early-done at scale
            ..EnvVariant::default()
        },
        EnvVariant {
            name: "visc".into(),
            nu_scale: 1.5,
            alpha: Some(0.8),
            ..EnvVariant::default()
        },
    ];

    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    // Explicit backend handle (registry bypass) so the batched-stepping
    // counters stay reachable after the pool takes ownership.
    let backend = Arc::new(BurgersBackend::new(&cfg.burgers).unwrap());
    let mut pool = EnvPool::with_backend(cfg, backend.clone(), &orch).unwrap();
    let c0 = pool.counters();
    assert_eq!(c0.threads_spawned, 64);
    assert_eq!(c0.envs_built, 64);
    assert_eq!(c0.grids_built, 1, "one shared resolved-truth context");

    let mut rng = Rng::new(2);
    let before = orch.stats().sub_ops;
    let r = pool
        .collect_with(&orch, &Protocol::new("bsmoke"), stub_policy, &mut rng, false, 16)
        .unwrap();
    let delta = orch.stats().sub_ops - before;
    orch.clear();

    assert_eq!(r.episodes.len(), 64);
    let mut variant_returns = vec![(0.0f64, 0usize); 3];
    for ep in &r.episodes {
        let want_steps = match ep.variant {
            1 => 3, // short horizon
            _ => 5,
        };
        assert_eq!(ep.steps.len(), want_steps, "variant {}", ep.variant);
        for s in &ep.steps {
            assert!(s.reward.is_finite() && s.reward > -1.0 && s.reward <= 1.0);
            assert!(s.act.iter().all(|a| a.is_finite()));
            assert_eq!(s.act.len(), 4, "one action per segment");
        }
        let (sum, n) = &mut variant_returns[ep.variant];
        *sum += ep.total_reward();
        *n += 1;
    }
    // Per-variant metrics: every family sampled (round-robin over 64
    // envs), every mean finite.
    for (v, (sum, n)) in variant_returns.iter().enumerate() {
        assert!(*n >= 21, "variant {v} starved: {n} episodes");
        assert!((sum / *n as f64).is_finite());
    }

    // O(E) per wave at pool scale: linear budget holds, and the old
    // per-event-rebuild cost (>= E ops per event, E*T events) is
    // decisively excluded.
    let (e, t) = (64u64, 5u64);
    assert!(delta <= 8 * e * (t + 2), "not O(E): {delta} ops");
    assert!(delta < e * e * t, "quadratic-regime op count: {delta}");

    // The flattened dataset feeds the PPO update: one row per
    // agent-sample, features = points / segments.
    let ds = flatten(&r.episodes, 48 / 4, 0.995, 1.0);
    let total_steps: usize = r.episodes.iter().map(|e| e.steps.len()).sum();
    assert_eq!(ds.len(), total_steps * 4);
    let mb = ds.minibatch_indices(64, &mut rng);
    assert!(!mb.is_empty());

    // Every env step went through the shared batched solver path, and
    // the waves genuinely coalesced: with min_batch = 16 each policy
    // flush releases >= 16 actions at once, so at least one wave must
    // have advanced several envs together (workers stage their steps
    // while the leader holds the grace window open).
    let bc = backend.batch_counters();
    assert_eq!(bc.envs_stepped, total_steps, "steps outside the batched path");
    assert!(bc.waves <= bc.envs_stepped);
    assert!(
        bc.max_wave >= 2,
        "64 concurrent envs never coalesced into a wave (waves={}, max={})",
        bc.waves,
        bc.max_wave
    );
}

#[test]
fn smoke_one_iteration_two_envs() {
    // The CI smoke entry: one sampling iteration with two envs through
    // the full worker-pool + orchestrator + collector stack, then the
    // trajectory pipeline.  (The PPO update itself needs compiled
    // artifacts; integration_training covers it when they exist.)
    let cfg = tiny_cfg(2);
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(11), &orch).unwrap();
    let mut rng = Rng::new(1);
    let r = pool
        .collect_with(&orch, &Protocol::new("smoke"), stub_policy, &mut rng, false, 2)
        .unwrap();
    assert_eq!(r.episodes.len(), 2);
    let feat = 6usize.pow(3) * 3;
    let ds = flatten(&r.episodes, feat, 0.995, 1.0);
    assert!(!ds.is_empty());
    let mb = ds.minibatch_indices(16, &mut rng);
    assert!(!mb.is_empty());
}
