//! Integration: the persistent event-driven environment runtime.
//!
//! These tests drive the real worker pool (OS threads, real LES solver,
//! real orchestrator traffic) through `EnvPool::collect_with` with a
//! deterministic closure standing in for the compiled policy, so they run
//! without `make artifacts`:
//!
//! * steady-state iterations spawn zero threads and rebuild zero
//!   `LesEnv`/`Grid` instances (the PR's acceptance counter test);
//! * event-driven full-batch collection reproduces the lock-step
//!   reference bit-for-bit under a fixed seed — including heterogeneous
//!   pools where a short-horizon variant terminates early (the
//!   early-done deadlock regression);
//! * `min_batch = 1` (fully event-driven) still completes every episode
//!   with correct per-variant bookkeeping;
//! * the zero-copy exchange allocates no tensor buffers after the warm-up
//!   iteration (`PoolCounters::exchange_allocs`, the CI allocation gate).

use relexi::config::{CaseConfig, EnvVariant, RunConfig};
use relexi::coordinator::EnvPool;
use relexi::orchestrator::{Orchestrator, Protocol};
use relexi::rl::{flatten, Episode};
use relexi::runtime::stub_policy;
use relexi::solver::dns::{generate, Truth, TruthParams};
use relexi::util::Rng;
use std::sync::Arc;

/// Tiny 12^3 / 2^3-element case: 3 actions per episode at t_end = 0.3.
fn tiny_cfg(n_envs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "tiny".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    cfg.solver.t_end = 0.3;
    cfg.solver.dns_points = 24;
    cfg.rl.n_envs = n_envs;
    cfg
}

fn tiny_truth(seed: u64) -> Arc<Truth> {
    Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: 1.0 / 45.0,
            ke_target: 1.5,
            spinup_time: 0.5,
            n_states: 3,
            sample_interval: 0.2,
            seed,
        },
        |_, _| {},
    ))
}

/// Three scenario families: base, a half-horizon variant (terminates two
/// steps early relative to the base 4-step episode) and a high-viscosity
/// variant, with disjoint initial-state families.
fn heterogeneous_cfg() -> RunConfig {
    let mut cfg = tiny_cfg(4);
    cfg.solver.t_end = 0.4; // base horizon: 4 actions
    cfg.rl.variants = vec![
        EnvVariant::default(),
        EnvVariant {
            name: "short".into(),
            t_end_scale: 0.5,
            ..EnvVariant::default()
        },
        EnvVariant {
            name: "visc".into(),
            nu_scale: 2.0,
            alpha: Some(0.8),
            ..EnvVariant::default()
        },
    ];
    cfg.rl.split_init_pool = true;
    cfg
}

fn assert_episodes_identical(a: &[Episode], b: &[Episode]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.variant, y.variant, "env {i} variant");
        assert_eq!(x.steps.len(), y.steps.len(), "env {i} episode length");
        for (t, (sx, sy)) in x.steps.iter().zip(&y.steps).enumerate() {
            assert_eq!(sx.obs, sy.obs, "env {i} step {t} obs");
            assert_eq!(sx.act, sy.act, "env {i} step {t} act");
            assert_eq!(sx.logp, sy.logp, "env {i} step {t} logp");
            assert_eq!(sx.value, sy.value, "env {i} step {t} value");
            assert_eq!(
                sx.reward.to_bits(),
                sy.reward.to_bits(),
                "env {i} step {t} reward"
            );
        }
    }
}

#[test]
fn steady_state_spawns_nothing_and_rebuilds_nothing() {
    let cfg = tiny_cfg(3);
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(33), &orch).unwrap();

    let c0 = pool.counters();
    assert_eq!(c0.threads_spawned, n_envs);
    assert_eq!(c0.envs_built, n_envs);
    assert_eq!(c0.grids_built, 1);
    assert_eq!(c0.iterations, 0);

    let mut rng = Rng::new(5);
    for it in 0..3 {
        let proto = Protocol::new(&format!("it{it}"));
        let rollouts = pool
            .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs)
            .unwrap();
        orch.clear();
        assert_eq!(rollouts.episodes.len(), n_envs);
        for ep in &rollouts.episodes {
            assert_eq!(ep.steps.len(), 3, "t_end/dt_rl = 3 actions");
            for s in &ep.steps {
                assert!(s.reward.is_finite() && s.reward > -1.0 && s.reward <= 1.0);
            }
        }
        assert!(rollouts.sample_time_s > 0.0);
    }

    // The acceptance gate: iterations >= 1 spawned zero threads and
    // rebuilt zero LesEnv/Grid instances.
    let c1 = pool.counters();
    assert_eq!(c1.threads_spawned, c0.threads_spawned);
    assert_eq!(c1.envs_built, c0.envs_built);
    assert_eq!(c1.grids_built, c0.grids_built);
    assert_eq!(c1.iterations, 3);
}

#[test]
fn event_full_batch_matches_lockstep_bitwise() {
    // Same seed, same truth, two independent pools: the event-driven
    // collector at min_batch = n_envs must reproduce the lock-step
    // reference bit-for-bit — heterogeneous horizons included (the short
    // variant raises its done-flag two steps before the base horizon,
    // which deadlocked the seed's gather loop).
    let cfg = heterogeneous_cfg();
    let n_envs = cfg.rl.n_envs;
    let truth = tiny_truth(77);

    let orch_a = Orchestrator::launch(4);
    let mut pool_a = EnvPool::new(cfg.clone(), truth.clone(), &orch_a).unwrap();
    let mut rng_a = Rng::new(42);
    let lockstep = pool_a
        .collect_lockstep_with(
            &orch_a,
            &Protocol::new("cmp"),
            stub_policy,
            &mut rng_a,
            false,
        )
        .unwrap();

    let orch_b = Orchestrator::launch(4);
    let mut pool_b = EnvPool::new(cfg.clone(), truth, &orch_b).unwrap();
    let mut rng_b = Rng::new(42);
    let event = pool_b
        .collect_with(
            &orch_b,
            &Protocol::new("cmp"),
            stub_policy,
            &mut rng_b,
            false,
            n_envs,
        )
        .unwrap();

    assert_episodes_identical(&lockstep.episodes, &event.episodes);
    // And the trainer RNGs advanced identically.
    assert_eq!(rng_a.next_u64(), rng_b.next_u64());

    // Episode lengths follow the variants: 4 (base), 2 (short), 4 (visc),
    // 4 (base again, round-robin).
    let lens: Vec<usize> = event.episodes.iter().map(|e| e.steps.len()).collect();
    assert_eq!(lens, vec![4, 2, 4, 4]);
    let variants: Vec<usize> = event.episodes.iter().map(|e| e.variant).collect();
    assert_eq!(variants, vec![0, 1, 2, 0]);
}

#[test]
fn min_batch_one_completes_heterogeneous_pool() {
    let cfg = heterogeneous_cfg();
    let orch = Orchestrator::launch(4);
    let mut pool = EnvPool::new(cfg, tiny_truth(77), &orch).unwrap();
    let mut rng = Rng::new(9);
    let r = pool
        .collect_with(&orch, &Protocol::new("mb1"), stub_policy, &mut rng, false, 1)
        .unwrap();

    let lens: Vec<usize> = r.episodes.iter().map(|e| e.steps.len()).collect();
    assert_eq!(lens, vec![4, 2, 4, 4]);
    for ep in &r.episodes {
        for s in &ep.steps {
            assert!(s.reward.is_finite() && s.reward > -1.0 && s.reward <= 1.0);
            assert!(s.act.iter().all(|a| a.is_finite()));
        }
    }
    // The flattened dataset still has one row per element-sample.
    let feat = 6usize.pow(3) * 3;
    let ds = flatten(&r.episodes, feat, 0.995, 1.0);
    assert_eq!(ds.len(), (4 + 2 + 4 + 4) * 8);
}

#[test]
fn steady_state_exchange_allocates_nothing() {
    // The PR-3 acceptance gate (run explicitly by the CI smoke job): the
    // tensor pools — per-worker observation buffers, the trainer's action
    // buffers — warm up during iteration 0 and must never allocate again.
    // Rollouts are dropped before the next iteration (as the training
    // loop does after its update phase), which releases every shared
    // buffer back to its pool.
    let cfg = tiny_cfg(3);
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(21), &orch).unwrap();
    let mut rng = Rng::new(8);

    let mut allocs_after = Vec::new();
    for it in 0..4 {
        let proto = Protocol::new(&format!("za{it}"));
        let r = pool
            .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs)
            .unwrap();
        assert_eq!(r.episodes.len(), n_envs);
        orch.clear();
        allocs_after.push(pool.counters().exchange_allocs);
        // `r` (the only holder of the shared buffers) drops here.
    }
    assert!(
        allocs_after[0] > 0,
        "pools must warm up during iteration 0"
    );
    for it in 1..4 {
        assert_eq!(
            allocs_after[it],
            allocs_after[0],
            "iteration {it} allocated exchange buffers in steady state: {allocs_after:?}"
        );
    }
}

#[test]
fn smoke_one_iteration_two_envs() {
    // The CI smoke entry: one sampling iteration with two envs through
    // the full worker-pool + orchestrator + collector stack, then the
    // trajectory pipeline.  (The PPO update itself needs compiled
    // artifacts; integration_training covers it when they exist.)
    let cfg = tiny_cfg(2);
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::new(cfg, tiny_truth(11), &orch).unwrap();
    let mut rng = Rng::new(1);
    let r = pool
        .collect_with(&orch, &Protocol::new("smoke"), stub_policy, &mut rng, false, 2)
        .unwrap();
    assert_eq!(r.episodes.len(), 2);
    let feat = 6usize.pow(3) * 3;
    let ds = flatten(&r.episodes, feat, 0.995, 1.0);
    assert!(!ds.is_empty());
    let mb = ds.minibatch_indices(16, &mut rng);
    assert!(!mb.is_empty());
}
