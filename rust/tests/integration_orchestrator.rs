//! Integration: orchestrator under realistic concurrent load — many env
//! workers exchanging full-size state/action tensors with one trainer, on
//! both backends (single-shard Redis-like, sharded KeyDB-like).

use relexi::orchestrator::{Orchestrator, Protocol};
use std::sync::Arc;
use std::time::Duration;

fn run_exchange(shards: usize, n_envs: usize, steps: usize, state_len: usize) {
    let orch = Arc::new(Orchestrator::launch(shards));
    let proto = Protocol::new("x");
    let mut workers = Vec::new();
    for i in 0..n_envs {
        let c = orch.client();
        let p = proto.clone();
        workers.push(std::thread::spawn(move || {
            for t in 0..steps {
                let payload: Vec<f32> = (0..state_len).map(|k| (i * 1000 + t + k) as f32).collect();
                c.put_tensor(&p.state_key(i, t), vec![state_len], payload);
                let act = c
                    .poll_take(&p.action_key(i, t), Duration::from_secs(30))
                    .expect("action");
                let (_, data) = act.as_tensor().unwrap();
                // Action payload must be the one addressed to this env+step.
                assert_eq!(data[0], (i * 7 + t) as f32, "env {i} step {t} got wrong action");
            }
            c.put_flag(&p.done_key(i), true);
        }));
    }

    let trainer = orch.client();
    for t in 0..steps {
        for i in 0..n_envs {
            let st = trainer
                .poll(&proto.state_key(i, t), Duration::from_secs(30))
                .expect("state");
            let (shape, data) = st.as_tensor().unwrap();
            assert_eq!(shape, &[state_len]);
            assert_eq!(data[0], (i * 1000 + t) as f32);
        }
        for i in 0..n_envs {
            trainer.put_tensor(&proto.action_key(i, t), vec![4], vec![(i * 7 + t) as f32; 4]);
        }
    }
    for i in 0..n_envs {
        assert_eq!(
            trainer
                .poll(&proto.done_key(i), Duration::from_secs(30))
                .unwrap()
                .as_flag(),
            Some(true)
        );
    }
    for w in workers {
        w.join().unwrap();
    }

    let stats = orch.stats();
    // Every state and action was written exactly once, plus done flags.
    assert_eq!(stats.puts as usize, 2 * n_envs * steps + n_envs);
    assert!(stats.bytes_in > 0 && stats.bytes_out > 0);
}

#[test]
fn lockstep_exchange_single_shard() {
    run_exchange(1, 8, 10, 1024);
}

#[test]
fn lockstep_exchange_sharded() {
    run_exchange(16, 8, 10, 1024);
}

#[test]
fn lockstep_exchange_many_envs() {
    run_exchange(8, 32, 5, 512);
}

#[test]
fn clear_between_iterations_isolates_runs() {
    let orch = Orchestrator::launch(4);
    let c = orch.client();
    let p0 = Protocol::new("it0");
    let p1 = Protocol::new("it1");
    c.put_tensor(&p0.state_key(0, 0), vec![2], vec![1.0, 2.0]);
    orch.clear();
    assert!(c.get(&p0.state_key(0, 0)).is_none());
    c.put_tensor(&p1.state_key(0, 0), vec![2], vec![3.0, 4.0]);
    assert_eq!(
        c.get(&p1.state_key(0, 0)).unwrap().as_tensor().unwrap().1,
        &[3.0, 4.0]
    );
}

#[test]
fn event_driven_exchange_arrival_order() {
    // The collector's consumption pattern: instead of polling env states
    // in fixed order, the trainer subscribes to all outstanding state
    // keys at once and serves whichever env arrives first.  Workers get
    // deliberately skewed delays so arrival order differs from env order.
    let n_envs = 8usize;
    let steps = 6usize;
    let orch = Arc::new(Orchestrator::launch(8));
    let proto = Protocol::new("ev");
    let mut workers = Vec::new();
    for i in 0..n_envs {
        let c = orch.client();
        let p = proto.clone();
        workers.push(std::thread::spawn(move || {
            for t in 0..steps {
                // env 7 is slowest at even steps, env 0 at odd ones.
                let delay = if t % 2 == 0 { i } else { n_envs - 1 - i };
                std::thread::sleep(Duration::from_millis(2 * delay as u64));
                c.put_tensor(&p.state_key(i, t), vec![1], vec![(i * 100 + t) as f32]);
                let act = c
                    .poll_take(&p.action_key(i, t), Duration::from_secs(30))
                    .expect("action");
                assert_eq!(act.as_tensor().unwrap().1[0], (i * 7 + t) as f32);
            }
            c.put_flag(&p.done_key(i), true);
        }));
    }

    let trainer = orch.client();
    for t in 0..steps {
        // Subscribe to the whole wave; take states in arrival order.
        let names: Vec<String> = (0..n_envs).map(|i| proto.state_key(i, t)).collect();
        let mut waiting: Vec<(usize, &str)> =
            names.iter().enumerate().map(|(i, k)| (i, k.as_str())).collect();
        while !waiting.is_empty() {
            let keys: Vec<&str> = waiting.iter().map(|&(_, k)| k).collect();
            let (hit, v) = trainer
                .poll_any_take(&keys, Duration::from_secs(30))
                .expect("state");
            let (env, _) = waiting.remove(hit);
            assert_eq!(v.as_tensor().unwrap().1[0], (env * 100 + t) as f32);
            trainer.put_tensor(&proto.action_key(env, t), vec![1], vec![(env * 7 + t) as f32]);
        }
    }
    for i in 0..n_envs {
        assert!(trainer
            .poll(&proto.done_key(i), Duration::from_secs(30))
            .unwrap()
            .as_flag()
            .unwrap());
    }
    for w in workers {
        w.join().unwrap();
    }
}

#[test]
fn early_done_env_does_not_stall_the_gather() {
    // Regression for the seed deadlock: an env that raises its done-flag
    // before exhausting the step budget must not leave the trainer
    // blocking on a state key that will never arrive.  The trainer
    // subscribes to {state, done} per env and wave, exactly like the
    // rollout collector.
    let orch = Arc::new(Orchestrator::launch(4));
    let proto = Protocol::new("ed");
    let budget = 5usize; // trainer's nominal step budget
    let early = 2usize; // env 1 terminates after this many steps
    let mut workers = Vec::new();
    for (i, horizon) in [(0usize, budget), (1usize, early)] {
        let c = orch.client();
        let p = proto.clone();
        workers.push(std::thread::spawn(move || {
            for t in 0..horizon {
                c.put_tensor(&p.state_key(i, t), vec![1], vec![t as f32]);
                c.poll_take(&p.action_key(i, t), Duration::from_secs(30))
                    .expect("action");
            }
            c.put_flag(&p.done_key(i), true);
        }));
    }

    let trainer = orch.client();
    let t0 = std::time::Instant::now();
    let mut done = [false; 2];
    let mut served = [0usize; 2];
    for t in 0..budget {
        for i in 0..2 {
            if done[i] {
                continue;
            }
            let state_key = proto.state_key(i, t);
            let done_key = proto.done_key(i);
            let (hit, _) = trainer
                .poll_any_take(&[&state_key, &done_key], Duration::from_secs(30))
                .expect("state or done");
            if hit == 1 {
                done[i] = true;
            } else {
                trainer.put_tensor(&proto.action_key(i, t), vec![1], vec![0.1]);
                served[i] += 1;
            }
        }
    }
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(served, [budget, early]);
    // The whole exchange must finish in interactive time — nowhere near a
    // poll-timeout stall.
    assert!(t0.elapsed() < Duration::from_secs(20));
}

#[test]
fn poll_timeout_does_not_wedge_under_load() {
    let orch = Arc::new(Orchestrator::launch(2));
    // A writer hammers unrelated keys while a reader waits for a key that
    // never arrives: the reader must still time out promptly.
    let w = {
        let orch = orch.clone();
        std::thread::spawn(move || {
            let c = orch.client();
            for i in 0..10_000 {
                c.put_scalar(&format!("noise{i}"), i as f64);
            }
        })
    };
    let c = orch.client();
    let t0 = std::time::Instant::now();
    assert!(c.poll("never", Duration::from_millis(100)).is_none());
    assert!(t0.elapsed() < Duration::from_secs(5));
    w.join().unwrap();
}
