//! Integration: orchestrator under realistic concurrent load — many env
//! workers exchanging full-size state/action tensors with one trainer, on
//! both backends (single-shard Redis-like, sharded KeyDB-like).

use relexi::orchestrator::{Orchestrator, Protocol};
use std::sync::Arc;
use std::time::Duration;

fn run_exchange(shards: usize, n_envs: usize, steps: usize, state_len: usize) {
    let orch = Arc::new(Orchestrator::launch(shards));
    let proto = Protocol::new("x");
    let mut workers = Vec::new();
    for i in 0..n_envs {
        let c = orch.client();
        let p = proto.clone();
        workers.push(std::thread::spawn(move || {
            for t in 0..steps {
                let payload: Vec<f32> = (0..state_len).map(|k| (i * 1000 + t + k) as f32).collect();
                c.put_tensor(&p.state_key(i, t), vec![state_len], payload);
                let act = c
                    .poll_take(&p.action_key(i, t), Duration::from_secs(30))
                    .expect("action");
                let (_, data) = act.as_tensor().unwrap();
                // Action payload must be the one addressed to this env+step.
                assert_eq!(data[0], (i * 7 + t) as f32, "env {i} step {t} got wrong action");
            }
            c.put_flag(&p.done_key(i), true);
        }));
    }

    let trainer = orch.client();
    for t in 0..steps {
        for i in 0..n_envs {
            let st = trainer
                .poll(&proto.state_key(i, t), Duration::from_secs(30))
                .expect("state");
            let (shape, data) = st.as_tensor().unwrap();
            assert_eq!(shape, &[state_len]);
            assert_eq!(data[0], (i * 1000 + t) as f32);
        }
        for i in 0..n_envs {
            trainer.put_tensor(&proto.action_key(i, t), vec![4], vec![(i * 7 + t) as f32; 4]);
        }
    }
    for i in 0..n_envs {
        assert_eq!(
            trainer
                .poll(&proto.done_key(i), Duration::from_secs(30))
                .unwrap()
                .as_flag(),
            Some(true)
        );
    }
    for w in workers {
        w.join().unwrap();
    }

    let stats = orch.stats();
    // Every state and action was written exactly once, plus done flags.
    assert_eq!(stats.puts as usize, 2 * n_envs * steps + n_envs);
    assert!(stats.bytes_in > 0 && stats.bytes_out > 0);
}

#[test]
fn lockstep_exchange_single_shard() {
    run_exchange(1, 8, 10, 1024);
}

#[test]
fn lockstep_exchange_sharded() {
    run_exchange(16, 8, 10, 1024);
}

#[test]
fn lockstep_exchange_many_envs() {
    run_exchange(8, 32, 5, 512);
}

#[test]
fn clear_between_iterations_isolates_runs() {
    let orch = Orchestrator::launch(4);
    let c = orch.client();
    let p0 = Protocol::new("it0");
    let p1 = Protocol::new("it1");
    c.put_tensor(&p0.state_key(0, 0), vec![2], vec![1.0, 2.0]);
    orch.clear();
    assert!(c.get(&p0.state_key(0, 0)).is_none());
    c.put_tensor(&p1.state_key(0, 0), vec![2], vec![3.0, 4.0]);
    assert_eq!(
        c.get(&p1.state_key(0, 0)).unwrap().as_tensor().unwrap().1,
        &[3.0, 4.0]
    );
}

#[test]
fn poll_timeout_does_not_wedge_under_load() {
    let orch = Arc::new(Orchestrator::launch(2));
    // A writer hammers unrelated keys while a reader waits for a key that
    // never arrives: the reader must still time out promptly.
    let w = {
        let orch = orch.clone();
        std::thread::spawn(move || {
            let c = orch.client();
            for i in 0..10_000 {
                c.put_scalar(&format!("noise{i}"), i as f64);
            }
        })
    };
    let c = orch.client();
    let t0 = std::time::Instant::now();
    assert!(c.poll("never", Duration::from_millis(100)).is_none());
    assert!(t0.elapsed() < Duration::from_secs(5));
    w.join().unwrap();
}
