//! Backend conformance suite: every in-tree `CfdEnv` backend must
//! satisfy the contract the solver-agnostic rollout stack relies on.
//! Each property runs against **both** registered backends (`les`,
//! `burgers`) through the same registry path the env pool uses:
//!
//! * fixed-RNG determinism (same seed -> bitwise-identical episodes),
//!   and RNG-independent test-state resets;
//! * `obs_len` == the exact number of floats `observe_into` fills;
//! * done-flag monotonicity: false for every step before the horizon,
//!   true exactly at it;
//! * reward finite and inside the Eq. (5) range at every step;
//! * the trait-provided allocating `reset`/`observe` defaults agree
//!   with the in-place core they derive from.

use relexi::config::{BurgersConfig, CaseConfig, RunConfig};
use relexi::rl::{backend_from_config, CfdBackend, CfdEnv};
use relexi::solver::dns::{generate, TruthParams};
use relexi::util::Rng;
use std::sync::Arc;

/// Build both backends on small, fast configurations.  Returns
/// `(run config, backend)` pairs so tests can resolve variants.
fn all_backends() -> Vec<(RunConfig, Arc<dyn CfdBackend>)> {
    // LES: the 12^3 / 2^3-element tiny case used across the suite.
    let mut les = RunConfig::default();
    les.case = CaseConfig {
        name: "tiny".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    les.solver.t_end = 0.3;
    les.solver.dns_points = 24;
    let truth = Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: les.solver.nu,
            ke_target: les.solver.ke_target,
            spinup_time: 0.5,
            n_states: 3,
            sample_interval: 0.2,
            seed: 91,
        },
        |_, _| {},
    ));
    let les_backend = backend_from_config(&les, Some(truth)).unwrap();

    // Burgers: 48 points, 4 segments, 3 actions.
    let mut bur = RunConfig::default();
    bur.rl.backend = "burgers".to_string();
    bur.burgers = BurgersConfig {
        points: 48,
        segments: 4,
        k_max: 6,
        t_end: 0.3,
        truth_states: 3,
        truth_spinup: 0.6,
        truth_interval: 0.2,
        ..BurgersConfig::default()
    };
    let bur_backend = backend_from_config(&bur, None).unwrap();

    vec![(les, les_backend), (bur, bur_backend)]
}

fn make_env(cfg: &RunConfig, backend: &Arc<dyn CfdBackend>) -> Box<dyn CfdEnv> {
    backend.make_env(&cfg.base_resolved()).unwrap()
}

#[test]
fn shapes_are_consistent_and_observe_into_fills_obs_len() {
    for (cfg, backend) in all_backends() {
        let name = backend.name().to_string();
        let mut env = make_env(&cfg, &backend);
        assert!(env.n_agents() >= 1, "{name}");
        assert!(env.n_actions() >= 1, "{name}");
        assert_eq!(
            env.obs_len() % env.n_agents(),
            0,
            "{name}: obs must split evenly over agents"
        );
        let mut rng = Rng::new(12);
        env.reset_in_place(&mut rng, false);
        // Every float of an obs_len-sized buffer is overwritten.
        let mut buf = vec![f32::NAN; env.obs_len()];
        env.observe_into(&mut buf);
        assert!(
            buf.iter().all(|v| v.is_finite()),
            "{name}: observe_into must fill all {} floats",
            env.obs_len()
        );
        // The spectrum and its target are non-empty and finite.
        let spec = env.spectrum();
        assert!(!spec.is_empty() && spec.iter().all(|e| e.is_finite()), "{name}");
        let target = env.target_spectrum();
        assert!(!target.is_empty() && target.iter().all(|e| e.is_finite()), "{name}");
    }
}

#[test]
fn fixed_rng_episodes_are_bitwise_deterministic() {
    for (cfg, backend) in all_backends() {
        let name = backend.name().to_string();
        let mut e1 = make_env(&cfg, &backend);
        let mut e2 = make_env(&cfg, &backend);
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        assert_eq!(e1.reset(&mut r1, false), e2.reset(&mut r2, false), "{name}");
        let cs = vec![0.15; e1.n_agents()];
        loop {
            let (a, b) = (e1.step(&cs), e2.step(&cs));
            assert_eq!(a.reward.to_bits(), b.reward.to_bits(), "{name}");
            assert_eq!(a.spec_error.to_bits(), b.spec_error.to_bits(), "{name}");
            assert_eq!(a.done, b.done, "{name}");
            assert_eq!(e1.observe(), e2.observe(), "{name}");
            if a.done {
                break;
            }
        }
        // Identical RNG consumption across instances.
        assert_eq!(r1.next_u64(), r2.next_u64(), "{name}");
    }
}

#[test]
fn test_state_reset_is_rng_independent() {
    for (cfg, backend) in all_backends() {
        let name = backend.name().to_string();
        let mut e1 = make_env(&cfg, &backend);
        let mut e2 = make_env(&cfg, &backend);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(424_242);
        let o1 = e1.reset(&mut r1, true);
        let o2 = e2.reset(&mut r2, true);
        assert_eq!(o1, o2, "{name}: test state must not depend on the RNG");
        // And the episode stays identical (stochastic backends must pin
        // their internal noise for test episodes).
        let cs = vec![0.1; e1.n_agents()];
        let (a, b) = (e1.step(&cs), e2.step(&cs));
        assert_eq!(a.reward.to_bits(), b.reward.to_bits(), "{name}");
        // No caller draws consumed: both RNGs still at their seed state.
        assert_eq!(Rng::new(1).next_u64(), r1.next_u64(), "{name}");
    }
}

#[test]
fn done_flag_is_monotone_and_rewards_stay_finite() {
    for (cfg, backend) in all_backends() {
        let name = backend.name().to_string();
        let mut env = make_env(&cfg, &backend);
        let mut rng = Rng::new(5);
        env.reset_in_place(&mut rng, false);
        let cs = vec![0.2; env.n_agents()];
        let horizon = env.n_actions();
        for t in 0..horizon {
            let out = env.step(&cs);
            assert!(
                out.reward.is_finite() && out.reward > -1.0 && out.reward <= 1.0,
                "{name}: reward {} at step {t}",
                out.reward
            );
            assert!(out.spec_error.is_finite() && out.spec_error >= 0.0, "{name}");
            assert_eq!(
                out.done,
                t + 1 == horizon,
                "{name}: done must flip exactly at the horizon (step {t})"
            );
        }
        // A reset rearms the episode.
        env.reset_in_place(&mut rng, false);
        assert!(!env.step(&cs).done || horizon == 1, "{name}");
    }
}

#[test]
fn trait_default_reset_and_observe_match_the_in_place_core() {
    for (cfg, backend) in all_backends() {
        let name = backend.name().to_string();
        let mut e1 = make_env(&cfg, &backend);
        let mut e2 = make_env(&cfg, &backend);
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let a = e1.reset(&mut r1, false);
        e2.reset_in_place(&mut r2, false);
        let mut b = vec![0f32; e2.obs_len()];
        assert_eq!(a.len(), e2.obs_len(), "{name}");
        e2.observe_into(&mut b);
        assert_eq!(a, b, "{name}: reset == reset_in_place + observe_into");
        assert_eq!(r1.next_u64(), r2.next_u64(), "{name}: same RNG consumption");

        let cs = vec![0.1; e1.n_agents()];
        e1.step(&cs);
        e2.step(&cs);
        e2.observe_into(&mut b);
        assert_eq!(e1.observe(), b, "{name}: observe == observe_into");
    }
}

#[test]
fn init_families_partition_the_pool() {
    for (cfg, backend) in all_backends() {
        let name = backend.name().to_string();
        // All tiny truths have 3 states: 3 families of one state each.
        let mut rng = Rng::new(7);
        let mut per_family = Vec::new();
        for fam in 0..3 {
            let mut env = make_env(&cfg, &backend);
            env.set_init_family(fam, 3).unwrap();
            let a = env.reset(&mut rng, false);
            let b = env.reset(&mut rng, false);
            assert_eq!(a, b, "{name}: family {fam} has one state");
            per_family.push(a);
        }
        assert_ne!(per_family[0], per_family[1], "{name}");
        assert_ne!(per_family[1], per_family[2], "{name}");
        let mut env = make_env(&cfg, &backend);
        assert!(env.set_init_family(3, 4).is_err(), "{name}: empty family");
    }
}
